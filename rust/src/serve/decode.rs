//! Session-based incremental decode engine — O(1) work per token.
//!
//! The batching router in [`super`] recomputes a full fixed window per
//! request. For autoregressive generation that is O(N) redundant work
//! per token; the FMM decomposition makes it unnecessary (paper Sec. 3):
//! the causal far field is a running-moment recurrence and the near
//! field only ever needs the last `bandwidth` keys/values. This module
//! serves exactly that:
//!
//! ```text
//!  streams ──open_stream()──▶ session table (per-layer, per-head
//!          ──step(token)───▶  HeadState) ─────▶ scheduler thread:
//!                               drain ≤ max_steps queued steps from all
//!                               sessions (micro-batch), run each through
//!                               the host decoder, fan logits out
//! ```
//!
//! * [`HostDecoder`] — a multi-layer multi-head FMM transformer decoder
//!   on host tensors. `forward_batch` is the O(N²)-per-sequence
//!   reference; [`DecoderSession::step`] reproduces its rows one token
//!   at a time from O(1) state (pinned row-for-row by
//!   `tests/decode_engine.rs`).
//! * [`DecodeServer`] / [`DecodeClient`] / [`DecodeStream`] — the
//!   serving wrapper: sessions stream tokens, the scheduler micro-batches
//!   concurrent sessions' steps per wake-up, and shutdown uses the same
//!   explicit sentinel pattern as [`super::Server`] (no deadlock with
//!   live clients; late submits error cleanly).
//! * Tiered residency — `DecodeServerConfig::max_resident_sessions`
//!   caps how many sessions live in RAM; the LRU idle streams spill to
//!   a [`SessionStore`] ([`super::session_store`]) as self-validating
//!   snapshots and restore transparently, bit-exactly, when their next
//!   token arrives. Millions of mostly-idle streams then cost snapshot
//!   bytes (or disk), not resident sessions.
//! * Speculative streams — with `DecodeServerConfig::speculation` set,
//!   opened streams run draft-propose / verify-accept lookahead
//!   ([`super::speculative`]) over [`verify_window`] and the cheap
//!   [`DecoderSession::checkpoint`]/[`DecoderSession::rollback`] pair,
//!   alongside plain streams on the same scheduler. Speculation is
//!   throughput-only: token streams stay bit-identical to plain greedy.
//! * Prompted streams — [`DecodeClient::open_stream_with_prompt`]
//!   admits a stream with a pending prompt; the scheduler ingests it in
//!   chunked stacked passes ([`super::prefill`]) interleaved with
//!   decode rounds under `DecodeServerConfig::prefill_budget` (token
//!   count) and `DecodeServerConfig::prefill_budget_ms` (wall time, via
//!   an EWMA cost model), so TTFT rides GEMM throughput while decode
//!   latency stays bounded.
//! * Unified ragged-batch planner — by default
//!   (`DecodeServerConfig::unified_planner`) every wave's traffic —
//!   single decode steps, prompt chunks, speculative verify windows —
//!   runs as ONE stacked [`ragged_forward`] pass over the concatenated
//!   ragged panel (gather → pass → scatter → commit), instead of three
//!   separate phases. Per-stream logits are bit-identical either way.
//!
//! Everything here is pure host Rust — no PJRT — so the serving
//! architecture is exercised end-to-end by `cargo test` even where the
//! XLA backend is stubbed out.

use std::cell::Cell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::attention::incremental::{feature_map_code, u64_to_words, words_to_u64};
use crate::attention::multilevel::{self, HeadState, MAX_LEVELS};
use crate::attention::{fmm_attention, multilevel_attention, FeatureMap};
use crate::kernel::{self, PackedMat};
use crate::rng::Pcg64;
use crate::runtime::checkpoint::Leaf;
use crate::runtime::manifest::Dtype;
use crate::serve::prefill::{self, ChunkPlan, PendingPrefill, PrefillOut, PrefillQueue};
use crate::serve::prefix_cache::PrefixCache;
use crate::serve::session_store::{self, MemStore, SessionStore};
use crate::serve::speculative::{
    SpecFactory, SpecPlan, SpeculationConfig, SpeculativeSession,
};
use crate::telemetry::{EventKind, Registry, Telemetry, LATENCY_BOUNDS_S, ROWS_BOUNDS};
use crate::tensor::Tensor;
use crate::util::fnv1a64;

/// RMS-norm denominator guard (host model only).
const RMS_EPS: f32 = 1e-6;

/// Layout version of the optional `"ml"` snapshot leaf. Bumped if the
/// multilevel state's serialized form ever changes shape.
const ML_LEAF_VERSION: u32 = 1;

/// Architecture + attention hyperparameters of the host decoder.
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    pub layers: usize,
    pub heads: usize,
    pub d_model: usize,
    pub vocab: usize,
    /// Near-field band per head.
    pub bandwidth: usize,
    /// Far-field feature maps (paper Sec. 3.2.1).
    pub kernels: Vec<FeatureMap>,
    /// Blend weights `w1·near + w2·far` (paper eq. (11)).
    pub w1: f32,
    pub w2: f32,
    /// Far-field hierarchy depth ([`crate::attention::multilevel`]).
    /// `0` is the paper's flat low-rank far field — bit-identical to
    /// the pre-multilevel engine, including snapshot bytes; `L >= 1`
    /// carries dyadic block summaries with O(log n) decode state.
    pub levels: usize,
    /// Weight-init seed (the decoder is a deterministic function of it).
    pub seed: u64,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig {
            layers: 2,
            heads: 2,
            d_model: 32,
            vocab: 64,
            bandwidth: 8,
            kernels: vec![FeatureMap::Elu],
            w1: 0.6,
            w2: 0.9,
            levels: 0,
            seed: 0,
        }
    }
}

impl DecodeConfig {
    /// Stable hash of every field that determines the decoder's math —
    /// architecture, attention hyperparameters, and the weight seed
    /// (the decoder is a deterministic function of the seed, so equal
    /// fingerprints mean bit-identical models). Session snapshots are
    /// stamped with it; restore refuses a mismatch.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(64 + self.kernels.len() * 8);
        for x in [
            self.layers as u64,
            self.heads as u64,
            self.d_model as u64,
            self.vocab as u64,
            self.bandwidth as u64,
            self.seed,
            self.w1.to_bits() as u64,
            self.w2.to_bits() as u64,
        ] {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        bytes.push(self.kernels.len() as u8);
        for fm in &self.kernels {
            bytes.push(feature_map_code(*fm));
        }
        // Hierarchy depth joins the hash only when enabled: depth-0
        // fingerprints stay byte-identical to the pre-multilevel format,
        // so every existing v1 snapshot restores into a depth-0 config
        // unchanged, while any depth mismatch is a typed restore error.
        if self.levels > 0 {
            bytes.extend_from_slice(&(self.levels as u64).to_le_bytes());
        }
        fnv1a64(&bytes)
    }
}

/// Per-layer weights: attention projections + a small gated-free MLP,
/// all pre-packed into transposed panels ([`PackedMat`]) once at
/// construction — the decode loop multiplies through
/// [`kernel::matmul_prepacked`] and never re-packs a constant weight.
struct LayerWeights {
    wq: PackedMat,
    wk: PackedMat,
    wv: PackedMat,
    wo: PackedMat,
    /// MLP: d_model → 2·d_model → d_model with ReLU.
    w_up: PackedMat,
    w_down: PackedMat,
}

/// Host-side FMM transformer decoder (reference weights, seeded).
///
/// Every non-attention op is row-local (RMS-norm, projections, MLP,
/// residuals), so computing one row at a time — the incremental path —
/// performs bit-identical float work to the batch path; only attention
/// needs the [`HeadState`] recurrence to stay O(1) (flat) or O(log n)
/// (multilevel). All constant
/// weights are pre-packed ([`PackedMat`]), and the prepacked multiply
/// reduces every output row identically for every batch width — a
/// session's step is bit-identical whether it runs alone, inside a
/// [`step_many`] micro-batch, or after a spill/restore round-trip.
pub struct HostDecoder {
    cfg: DecodeConfig,
    embed: Tensor,
    layers: Vec<LayerWeights>,
    w_out: PackedMat,
}

impl HostDecoder {
    pub fn new(cfg: DecodeConfig) -> Result<HostDecoder> {
        if cfg.layers == 0 || cfg.heads == 0 || cfg.vocab == 0 {
            bail!("degenerate decoder config {cfg:?}");
        }
        if cfg.d_model == 0 || cfg.d_model % cfg.heads != 0 {
            bail!("d_model {} must be a positive multiple of heads {}", cfg.d_model, cfg.heads);
        }
        if cfg.bandwidth == 0 {
            bail!(
                "bandwidth must be >= 1: a zero near field degenerates the blend \
                 (drop the near term via w1 = 0 instead)"
            );
        }
        if cfg.kernels.is_empty() {
            bail!(
                "kernels must name at least one far-field feature map \
                 (elu | elu_neg | tanh)"
            );
        }
        if cfg.levels > MAX_LEVELS {
            bail!(
                "levels {} exceeds the multilevel hierarchy cap {MAX_LEVELS}",
                cfg.levels
            );
        }
        let d = cfg.d_model;
        let mut rng = Pcg64::seeded(cfg.seed);
        let proj = |rng: &mut Pcg64, rows: usize, cols: usize| {
            let t = Tensor::randn(&[rows, cols], rng).scale(1.0 / (rows as f32).sqrt());
            PackedMat::pack(t.data(), rows, cols)
        };
        let embed = Tensor::randn(&[cfg.vocab, d], &mut rng);
        let layers = (0..cfg.layers)
            .map(|_| LayerWeights {
                wq: proj(&mut rng, d, d),
                wk: proj(&mut rng, d, d),
                wv: proj(&mut rng, d, d),
                wo: proj(&mut rng, d, d),
                w_up: proj(&mut rng, d, 2 * d),
                w_down: proj(&mut rng, 2 * d, d),
            })
            .collect();
        let w_out = proj(&mut rng, d, cfg.vocab);
        Ok(HostDecoder { cfg, embed, layers, w_out })
    }

    pub fn config(&self) -> &DecodeConfig {
        &self.cfg
    }

    fn embed_row(&self, token: i32) -> Result<Tensor> {
        let t = usize::try_from(token).ok().filter(|&t| t < self.cfg.vocab).ok_or_else(
            || anyhow!("token {token} outside vocab 0..{}", self.cfg.vocab),
        )?;
        Tensor::new(&[1, self.cfg.d_model], self.embed.row(t).to_vec())
    }

    /// One transformer block over `m` rows, with attention supplied by
    /// the caller (batch `fmm_attention` or incremental state steps).
    fn block<F>(&self, l: usize, x: &Tensor, attend: F) -> Result<Tensor>
    where
        F: FnOnce(&Tensor, &Tensor, &Tensor) -> Result<Tensor>,
    {
        let lw = &self.layers[l];
        let h = rms_norm(x);
        let q = mm(&h, &lw.wq)?;
        let k = mm(&h, &lw.wk)?;
        let v = mm(&h, &lw.wv)?;
        let a = attend(&q, &k, &v)?;
        let x = x.add(&mm(&a, &lw.wo)?)?;
        let m = rms_norm(&x);
        let f = mm(&relu(mm(&m, &lw.w_up)?), &lw.w_down)?;
        x.add(&f)
    }

    /// Batch causal forward: `n × vocab` logits for a whole sequence.
    /// The O(N²) reference the incremental path is pinned against.
    pub fn forward_batch(&self, tokens: &[i32]) -> Result<Tensor> {
        let n = tokens.len();
        let d = self.cfg.d_model;
        let dh = d / self.cfg.heads;
        let mut x = Tensor::zeros(&[n, d]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = self.embed_row(t)?;
            x.data_mut()[i * d..(i + 1) * d].copy_from_slice(row.data());
        }
        for l in 0..self.cfg.layers {
            x = self.block(l, &x, |q, k, v| {
                let mut a = Tensor::zeros(&[n, d]);
                for head in 0..self.cfg.heads {
                    let qh = slice_cols(q, head * dh, dh);
                    let kh = slice_cols(k, head * dh, dh);
                    let vh = slice_cols(v, head * dh, dh);
                    // Depth 0 keeps the literal flat call (multilevel
                    // depth 0 is bit-identical to it anyway; the batch
                    // reference stays recognizably the paper's blend).
                    let oh = if self.cfg.levels == 0 {
                        fmm_attention(
                            &qh,
                            &kh,
                            &vh,
                            self.cfg.bandwidth,
                            &self.cfg.kernels,
                            self.cfg.w1,
                            self.cfg.w2,
                            true,
                        )
                    } else {
                        multilevel_attention(
                            &qh,
                            &kh,
                            &vh,
                            self.cfg.bandwidth,
                            &self.cfg.kernels,
                            self.cfg.w1,
                            self.cfg.w2,
                            self.cfg.levels,
                        )
                    };
                    write_cols(&mut a, head * dh, &oh);
                }
                Ok(a)
            })?;
        }
        mm(&rms_norm(&x), &self.w_out)
    }
}

/// `x @ w` against a pre-packed weight: [`Tensor::matmul`] minus the
/// per-call pack, and bitwise row-batch-invariant (see
/// [`kernel::matmul_prepacked`]).
fn mm(x: &Tensor, w: &PackedMat) -> Result<Tensor> {
    let &[m, k] = x.shape() else {
        bail!("mm needs a 2-D activation");
    };
    if k != w.rows() {
        bail!("mm inner dims {k} != {}", w.rows());
    }
    let mut out = Tensor::zeros(&[m, w.cols()]);
    kernel::matmul_prepacked(x.data(), w, out.data_mut(), m);
    Ok(out)
}

/// Per-stream decode state: one [`HeadState`] per layer per head (flat
/// at depth 0, multilevel otherwise). Holds
/// `layers · heads · O(bandwidth·dh + (levels+1)·r·dh²)` floats —
/// constant (depth 0) or logarithmic (depth ≥ 1) in tokens decoded.
pub struct DecoderSession {
    model: Arc<HostDecoder>,
    states: Vec<Vec<HeadState>>,
    pos: usize,
}

/// In-memory checkpoint of a session's full decode state: one raw-f32
/// [`HeadState::clone_state_into`] view per layer/head plus the
/// stream position. No byte codec, no framing — taking one and
/// [`DecoderSession::rollback`]-ing to it are plain buffer copies,
/// which is what makes speculative checkpoint/rollback
/// ([`super::speculative`]) nearly free on the O(1) FMM state.
#[derive(Debug, Clone, Default)]
pub struct SessionCheckpoint {
    states: Vec<Vec<f32>>,
    pos: usize,
}

impl SessionCheckpoint {
    /// Stream position (tokens consumed) when the checkpoint was taken.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Approximate bytes held — same order as the live state it mirrors.
    pub fn bytes(&self) -> usize {
        self.states.iter().map(|s| s.len() * std::mem::size_of::<f32>()).sum()
    }
}

impl DecoderSession {
    pub fn new(model: Arc<HostDecoder>) -> DecoderSession {
        let cfg = model.config();
        let dh = cfg.d_model / cfg.heads;
        let states = (0..cfg.layers)
            .map(|_| {
                (0..cfg.heads)
                    .map(|_| {
                        HeadState::for_config(
                            dh,
                            dh,
                            cfg.bandwidth,
                            &cfg.kernels,
                            cfg.w1,
                            cfg.w2,
                            cfg.levels,
                        )
                    })
                    .collect()
            })
            .collect();
        DecoderSession { model, states, pos: 0 }
    }

    /// Tokens consumed so far.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Consume one token, return the logits row — row `position()` of
    /// `forward_batch` over the full prefix, at O(1) cost.
    pub fn step(&mut self, token: i32) -> Result<Vec<f32>> {
        let cfg = self.model.config();
        let d = cfg.d_model;
        let dh = d / cfg.heads;
        let mut x = self.model.embed_row(token)?;
        for l in 0..cfg.layers {
            let states = &mut self.states[l];
            x = self.model.block(l, &x, |q, k, v| {
                // step_into writes each head's output slice in place:
                // no per-head allocation on the serving hot path.
                let mut a = Tensor::zeros(&[1, d]);
                let out = a.data_mut();
                for (head, st) in states.iter_mut().enumerate() {
                    let lo = head * dh;
                    st.step_into(
                        &q.data()[lo..lo + dh],
                        &k.data()[lo..lo + dh],
                        &v.data()[lo..lo + dh],
                        &mut out[lo..lo + dh],
                    );
                }
                Ok(a)
            })?;
        }
        self.pos += 1;
        Ok(mm(&rms_norm(&x), &self.model.w_out)?.into_data())
    }

    /// Bytes of decode state this session holds (attention ring buffers
    /// + far-field moments across all layers and heads) — constant in
    /// tokens decoded at depth 0, O(log n) at depth ≥ 1, and within
    /// framing overhead of what a spill writes to the [`SessionStore`].
    pub fn state_bytes(&self) -> usize {
        self.states.iter().flatten().map(|s| s.state_bytes()).sum()
    }

    /// Bytes currently held in multilevel far-field summaries across
    /// all layers and heads (0 for depth-0 sessions) — the O(log n)
    /// part of [`state_bytes`](Self::state_bytes).
    pub fn summary_bytes(&self) -> usize {
        self.states.iter().flatten().map(|s| s.summary_bytes()).sum()
    }

    /// Drain the per-head coarse-summary update counters accumulated
    /// since the last drain (0 for depth-0 sessions). Telemetry sync
    /// calls this so `decode.ml_summary_updates` meters work performed
    /// exactly once per merge/compress, across spills and rollbacks.
    pub fn drain_summary_updates(&mut self) -> u64 {
        self.states
            .iter_mut()
            .flatten()
            .map(|s| s.drain_summary_updates())
            .sum()
    }

    /// Serialize this session into a self-validating snapshot blob
    /// (format: [`session_store`] module docs): one leaf per
    /// layer/head raw decode state plus a position leaf, stamped with
    /// the model's config fingerprint.
    pub fn snapshot(&self) -> Result<Vec<u8>> {
        self.snapshot_with_draft(&[])
    }

    /// [`snapshot`](Self::snapshot) plus an optional trailing `draft`
    /// leaf carrying a bounded committed-token history (i32) — how a
    /// speculative stream's draft priming survives spills and
    /// prefix-cache forks. An empty `draft` emits the plain layout
    /// byte-for-byte, so plain-session snapshots are unchanged and the
    /// two restore interchangeably.
    pub fn snapshot_with_draft(&self, draft: &[i32]) -> Result<Vec<u8>> {
        let cfg = self.model.config();
        let mut leaves = Vec::with_capacity(3 + self.states.len() * self.states[0].len());
        leaves.push(Leaf::from_f32("pos", &[2], &u64_to_words(self.pos as u64)));
        // Versioned multilevel leaf, present only at depth >= 1: depth-0
        // snapshots stay byte-identical to the pre-multilevel layout, so
        // existing v1 blobs and depth-0 configs interoperate both ways.
        if cfg.levels > 0 {
            leaves.push(Leaf::from_f32(
                "ml",
                &[2],
                &[
                    f32::from_bits(ML_LEAF_VERSION),
                    f32::from_bits(cfg.levels as u32),
                ],
            ));
        }
        let mut buf = Vec::new();
        for (l, row) in self.states.iter().enumerate() {
            for (h, st) in row.iter().enumerate() {
                buf.clear();
                st.export_into(&mut buf);
                leaves.push(Leaf::from_f32(&format!("l{l}.h{h}"), &[buf.len()], &buf));
            }
        }
        if !draft.is_empty() {
            leaves.push(Leaf::from_i32("draft", &[draft.len()], draft));
        }
        session_store::encode_snapshot(self.model.config().fingerprint(), &leaves)
    }

    /// Rebuild a session from a [`snapshot`](Self::snapshot) blob.
    /// Validates the codec framing, the config fingerprint, and every
    /// per-head raw state; any mismatch or corruption is an `Err` that
    /// affects only this stream — never a panic. A trailing draft
    /// leaf (from [`snapshot_with_draft`](Self::snapshot_with_draft))
    /// is accepted and discarded.
    pub fn restore(model: Arc<HostDecoder>, snap: &[u8]) -> Result<DecoderSession> {
        Ok(DecoderSession::restore_with_draft(model, snap)?.0)
    }

    /// [`restore`](Self::restore) that also returns the draft-history
    /// leaf when the snapshot carries one (`None` for plain
    /// snapshots) — callers re-wrapping the session for speculative
    /// decoding feed it to [`DraftSource::observe_many`] so the fork
    /// proposes from token one.
    pub fn restore_with_draft(
        model: Arc<HostDecoder>,
        snap: &[u8],
    ) -> Result<(DecoderSession, Option<Vec<i32>>)> {
        let cfg = model.config().clone();
        let mut leaves = session_store::decode_snapshot(snap, cfg.fingerprint())?;
        let meta = 1 + usize::from(cfg.levels > 0);
        let want = meta + cfg.layers * cfg.heads;
        // At most one trailing "draft" leaf rides after the state
        // leaves; anything else with that count is malformed and falls
        // through to the count check below.
        let mut draft = None;
        if leaves.len() == want + 1 && leaves.last().map(|l| l.name.as_str()) == Some("draft") {
            let leaf = leaves.pop().expect("non-empty: len checked");
            if leaf.dtype != Dtype::I32 {
                bail!("snapshot draft leaf has dtype {:?}, expected i32", leaf.dtype);
            }
            draft = Some(leaf.to_i32());
        }
        if leaves.len() != want {
            bail!("snapshot has {} leaves, expected {want}", leaves.len());
        }
        if leaves.iter().any(|l| l.dtype != Dtype::F32) {
            bail!("snapshot contains a non-f32 leaf");
        }
        if leaves[0].name != "pos" || leaves[0].elems() != 2 {
            bail!("snapshot leaf 0 is {:?}, expected the position leaf", leaves[0].name);
        }
        let pos_words = leaves[0].to_f32();
        let pos64 = words_to_u64(pos_words[0], pos_words[1]);
        let pos = usize::try_from(pos64)
            .map_err(|_| anyhow!("snapshot position {pos64} overflows"))?;
        if cfg.levels > 0 {
            // The config fingerprint already separates depths; the leaf
            // pins the layout version and depth *inside* the blob too,
            // so a hand-corrupted or future-versioned snapshot degrades
            // to a typed error instead of a misparse.
            let leaf = &leaves[1];
            if leaf.name != "ml" || leaf.elems() != 2 {
                bail!("snapshot leaf 1 is {:?}, expected the multilevel leaf", leaf.name);
            }
            let words = leaf.to_f32();
            let (ver, depth) = (words[0].to_bits(), words[1].to_bits());
            if ver != ML_LEAF_VERSION {
                bail!("snapshot multilevel leaf version {ver}, expected {ML_LEAF_VERSION}");
            }
            if depth as usize != cfg.levels {
                bail!(
                    "snapshot multilevel depth {depth} does not match \
                     config depth {}",
                    cfg.levels
                );
            }
        }
        let mut sess = DecoderSession::new(model);
        let mut it = leaves[meta..].iter();
        for l in 0..cfg.layers {
            for h in 0..cfg.heads {
                let leaf = it.next().expect("leaf count checked");
                if leaf.name != format!("l{l}.h{h}") {
                    bail!("snapshot leaf {:?} out of order (expected l{l}.h{h})", leaf.name);
                }
                sess.states[l][h]
                    .import_from(&leaf.to_f32())
                    .with_context(|| format!("importing head state l{l}.h{h}"))?;
            }
        }
        sess.pos = pos;
        Ok((sess, draft))
    }

    /// The shared decoder this session streams through.
    pub fn model(&self) -> &Arc<HostDecoder> {
        &self.model
    }

    /// Capture an in-memory checkpoint of this session's decode state
    /// (raw-f32 views, no snapshot codec — cf. the heavier
    /// [`snapshot`](Self::snapshot) used for spills).
    /// [`rollback`](Self::rollback) returns to it bit-exactly.
    pub fn checkpoint(&self) -> SessionCheckpoint {
        let mut ckpt = SessionCheckpoint::default();
        self.checkpoint_into(&mut ckpt);
        ckpt
    }

    /// Allocation-reusing variant of [`checkpoint`](Self::checkpoint):
    /// overwrites `ckpt` in place, reusing its per-head buffers.
    pub fn checkpoint_into(&self, ckpt: &mut SessionCheckpoint) {
        let n: usize = self.states.iter().map(|row| row.len()).sum();
        ckpt.states.resize_with(n, Vec::new);
        let mut heads = ckpt.states.iter_mut();
        for row in &self.states {
            for st in row {
                st.clone_state_into(heads.next().expect("sized above"));
            }
        }
        ckpt.pos = self.pos;
    }

    /// Roll this session back to a [`checkpoint`](Self::checkpoint)
    /// taken on it — the bit-exact inverse, however many tokens were
    /// consumed in between. `Err` only on a checkpoint from a
    /// config-mismatched session (per-head fingerprints are validated);
    /// a partially applied mismatched rollback leaves the session
    /// untrustworthy, so callers must treat `Err` as fatal to the
    /// stream.
    pub fn rollback(&mut self, ckpt: &SessionCheckpoint) -> Result<()> {
        let n: usize = self.states.iter().map(|row| row.len()).sum();
        if ckpt.states.len() != n {
            bail!(
                "checkpoint carries {} head states, session has {n}",
                ckpt.states.len()
            );
        }
        let mut heads = ckpt.states.iter();
        for row in self.states.iter_mut() {
            for st in row.iter_mut() {
                st.restore_state_from(heads.next().expect("count checked"))?;
            }
        }
        self.pos = ckpt.pos;
        Ok(())
    }

    /// Ingest one prompt chunk as a single stacked pass — the prefill
    /// primitive ([`super::prefill`] owns the chunking loop and the
    /// scheduler bookkeeping around it).
    ///
    /// A thin [`ragged_forward`] builder: one segment, [`Emit::None`]
    /// (or [`Emit::Last`]). With `emit_logits` false the vocab readout —
    /// the widest GEMM in the model — is skipped entirely, which is what
    /// lets prompt ingest outrun scalar replay (a scalar
    /// [`step`](Self::step) pays the readout on every token). With
    /// `emit_logits` true, the *last* row's logits are returned: RMS
    /// norm is row-local and the prepacked readout reduces every row
    /// identically at any batch width, so that row is bit-identical to
    /// what `step(tokens[C-1])` would have returned at that point.
    ///
    /// An empty chunk is a no-op (`Ok(None)`); any out-of-vocab token
    /// fails the call before any state is touched.
    pub fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        emit_logits: bool,
    ) -> Result<Option<Vec<f32>>> {
        if tokens.is_empty() {
            return Ok(None);
        }
        let emit = if emit_logits { Emit::Last } else { Emit::None };
        let segs = [SegmentSpec { tokens, emit }];
        let mut sessions: [&mut DecoderSession; 1] = [self];
        let mut rows = ragged_forward(&mut sessions, &segs)?;
        Ok(rows.pop().expect("one segment").pop())
    }
}

/// Greedy (argmax) token choice over a logits row — NaN-safe, single
/// source for every greedy chain in the crate (the serving harnesses,
/// the speculative accept loop, draft model proposals).
pub fn greedy_argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0) as i32
}

/// Which logits rows a [`ragged_forward`] segment reads out.
///
/// The vocab readout is the widest GEMM in the model, so segments
/// declare the minimum they need: prompt chunks skip it entirely
/// ([`Emit::None`]) or pay for one row ([`Emit::Last`]); decode steps
/// and verify windows read every row ([`Emit::All`] — for a one-row
/// decode segment the two are the same row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Emit {
    /// No logits for this segment (non-final prefill chunk).
    None,
    /// Only the segment's last row (final prefill chunk).
    Last,
    /// Every row (decode step, speculative verify window).
    All,
}

/// One stream's slice of a ragged stacked pass: the tokens it consumes
/// this round and which logits rows it wants back.
pub(crate) struct SegmentSpec<'a> {
    pub(crate) tokens: &'a [i32],
    pub(crate) emit: Emit,
}

/// Drive one stacked pass over a *ragged* batch of per-stream windows —
/// the single forward spine behind every multi-row execution in the
/// crate: [`step_many`] (B one-token segments), [`verify_window`] (one
/// K+1-token segment), [`DecoderSession::prefill_chunk`] (one C-token
/// segment), and the unified scheduler planner (any mix at once).
///
/// Gather → stacked pass → scatter: every segment's tokens embed into
/// one `n`-row panel (`n = Σ len`), each transformer block runs as
/// `n`-row prepacked GEMMs over the concatenated panel while each
/// stream's per-head attention state advances through its own rows
/// chronologically ([`multilevel::advance_many_heads`] →
/// [`HeadState::step_window_into`]), and only the rows the
/// segments' [`Emit`] modes request go through the vocab readout.
/// Returns one `Vec` of logits rows per segment (empty under
/// [`Emit::None`]).
///
/// Row `j` of segment `i` reproduces `sessions[i].step(tokens[j])` at
/// that point *bit for bit*, whatever the batch composition: every
/// row-local op (embedding gather, RMS-norms, the projection/MLP/
/// readout multiplies) runs through [`kernel::matmul_prepacked`], whose
/// per-row reduction order is independent of the row count, and the
/// attention recurrence is the identical scalar chronological code per
/// state. This is the invariant that lets the scheduler fuse decode,
/// prefill, and speculative traffic into one pass per round without
/// ever perturbing a stream's logits.
///
/// All sessions must share one model (`Arc` identity); any invalid
/// token anywhere in the batch fails the whole call *before* any state
/// is touched (the embedding gather runs first), so callers pre-validate
/// when partial failure must not abort neighbors. Zero-length segments
/// are legal and yield no rows.
pub(crate) fn ragged_forward(
    sessions: &mut [&mut DecoderSession],
    segs: &[SegmentSpec],
) -> Result<Vec<Vec<Vec<f32>>>> {
    ragged_forward_spanned(sessions, segs, None)
}

/// Per-pass phase-duration accumulators for the sampled telemetry span
/// timeline ([`crate::telemetry`]). `Cell`s because the attention
/// closure only gets a shared borrow. Durations are measured with raw
/// `Instant` pairs — they are intervals, not ordered timestamps, so the
/// mockable telemetry clock buys nothing here.
#[derive(Default)]
pub(crate) struct SpanCells {
    /// Embedding gather + per-head column panel gather/scatter copies.
    pub(crate) gather_s: Cell<f64>,
    /// GEMM share of the blocks (projections, MLP, norms): whole-layer
    /// wall time minus the attend-closure interior.
    pub(crate) gemm_s: Cell<f64>,
    /// [`multilevel::advance_many_heads`] across all layers and heads.
    pub(crate) advance_s: Cell<f64>,
    /// Vocab readout (final RMS norm + the widest GEMM).
    pub(crate) readout_s: Cell<f64>,
}

/// [`ragged_forward`] with optional phase timing. `spans: None` is the
/// production fast path — not a single extra `Instant::now()` — and the
/// math is identical either way (timing is observation-only), so
/// sampled waves stay bit-identical to unsampled ones.
pub(crate) fn ragged_forward_spanned(
    sessions: &mut [&mut DecoderSession],
    segs: &[SegmentSpec],
    spans: Option<&SpanCells>,
) -> Result<Vec<Vec<Vec<f32>>>> {
    let b = sessions.len();
    assert_eq!(segs.len(), b, "one segment per session");
    if b == 0 {
        return Ok(Vec::new());
    }
    let model = sessions[0].model.clone();
    if !sessions.iter().all(|s| Arc::ptr_eq(&s.model, &model)) {
        bail!("stacked pass requires sessions sharing one model");
    }
    let lens: Vec<usize> = segs.iter().map(|s| s.tokens.len()).collect();
    let n: usize = lens.iter().sum();
    if n == 0 {
        return Ok(vec![Vec::new(); b]);
    }
    let cfg = model.config();
    let d = cfg.d_model;
    let dh = d / cfg.heads;
    // Embed every row first: an invalid token anywhere errors here,
    // before any attention state has advanced.
    let t_embed = spans.map(|_| Instant::now());
    let mut x = Tensor::zeros(&[n, d]);
    {
        let mut row = 0usize;
        for seg in segs {
            for &tok in seg.tokens {
                let e = model.embed_row(tok)?;
                x.data_mut()[row * d..(row + 1) * d].copy_from_slice(e.data());
                row += 1;
            }
        }
    }
    if let (Some(sp), Some(t)) = (spans, t_embed) {
        sp.gather_s.set(sp.gather_s.get() + t.elapsed().as_secs_f64());
    }
    for l in 0..cfg.layers {
        let t_layer = spans.map(|_| Instant::now());
        // Attend-closure interior wall time, reported out so the GEMM
        // share (whole layer minus interior) can be derived below.
        let inner_s = Cell::new(0.0f64);
        x = model.block(l, &x, |qt, kt, vt| {
            let t_inner = spans.map(|_| Instant::now());
            let mut adv_s = 0.0f64;
            let mut a = Tensor::zeros(&[n, d]);
            // Per-head column panels, scratch-backed: gather the head's
            // columns contiguously across the whole ragged batch,
            // advance every stream's state through its own rows, scatter
            // the outputs back. The gather costs O(n·d) copies against
            // the block's O(n·d²) math. No steady-state allocation.
            let mut qh = kernel::scratch(n * dh);
            let mut kh = kernel::scratch(n * dh);
            let mut vh = kernel::scratch(n * dh);
            let mut oh = kernel::scratch(n * dh);
            for head in 0..cfg.heads {
                let lo = head * dh;
                for t in 0..n {
                    qh[t * dh..(t + 1) * dh].copy_from_slice(&qt.row(t)[lo..lo + dh]);
                    kh[t * dh..(t + 1) * dh].copy_from_slice(&kt.row(t)[lo..lo + dh]);
                    vh[t * dh..(t + 1) * dh].copy_from_slice(&vt.row(t)[lo..lo + dh]);
                }
                let t_adv = spans.map(|_| Instant::now());
                let mut states: Vec<&mut HeadState> =
                    sessions.iter_mut().map(|s| &mut s.states[l][head]).collect();
                multilevel::advance_many_heads(&mut states, &lens, &qh, &kh, &vh, &mut oh);
                if let Some(t) = t_adv {
                    adv_s += t.elapsed().as_secs_f64();
                }
                for t in 0..n {
                    a.data_mut()[t * d + lo..t * d + lo + dh]
                        .copy_from_slice(&oh[t * dh..(t + 1) * dh]);
                }
            }
            if let (Some(sp), Some(t)) = (spans, t_inner) {
                let inner = t.elapsed().as_secs_f64();
                inner_s.set(inner);
                sp.advance_s.set(sp.advance_s.get() + adv_s);
                // The interior minus the recurrence is the panel
                // gather/scatter copy time.
                sp.gather_s.set(sp.gather_s.get() + (inner - adv_s).max(0.0));
            }
            Ok(a)
        })?;
        if let (Some(sp), Some(t)) = (spans, t_layer) {
            sp.gemm_s
                .set(sp.gemm_s.get() + (t.elapsed().as_secs_f64() - inner_s.get()).max(0.0));
        }
    }
    for (s, &len) in sessions.iter_mut().zip(&lens) {
        s.pos += len;
    }
    // Readout: gather only the rows the segments asked for. RMS norm is
    // row-local and the prepacked readout reduces every row identically
    // at any batch width, so reading a subset of rows cannot perturb
    // their values.
    let mut emit_rows: Vec<usize> = Vec::new();
    {
        let mut base = 0usize;
        for (seg, &len) in segs.iter().zip(&lens) {
            match seg.emit {
                Emit::None => {}
                Emit::Last => {
                    if len > 0 {
                        emit_rows.push(base + len - 1);
                    }
                }
                Emit::All => emit_rows.extend(base..base + len),
            }
            base += len;
        }
    }
    let mut out: Vec<Vec<Vec<f32>>> = segs.iter().map(|_| Vec::new()).collect();
    if emit_rows.is_empty() {
        return Ok(out);
    }
    let t_read = spans.map(|_| Instant::now());
    let logits = if emit_rows.len() == n {
        mm(&rms_norm(&x), &model.w_out)?
    } else {
        let mut sub = Tensor::zeros(&[emit_rows.len(), d]);
        for (i, &r) in emit_rows.iter().enumerate() {
            sub.data_mut()[i * d..(i + 1) * d].copy_from_slice(x.row(r));
        }
        mm(&rms_norm(&sub), &model.w_out)?
    };
    if let (Some(sp), Some(t)) = (spans, t_read) {
        sp.readout_s.set(sp.readout_s.get() + t.elapsed().as_secs_f64());
    }
    // Scatter: emit_rows was built walking the segments in order, so
    // the logits rows come back per segment, in row order.
    let mut next = 0usize;
    for (i, (seg, &len)) in segs.iter().zip(&lens).enumerate() {
        let count = match seg.emit {
            Emit::None => 0,
            Emit::Last => usize::from(len > 0),
            Emit::All => len,
        };
        for _ in 0..count {
            out[i].push(logits.row(next).to_vec());
            next += 1;
        }
    }
    Ok(out)
}

/// Drive a multi-token window through one session as a single stacked
/// step — the verify half of speculative decoding
/// ([`super::speculative`]) and a window-prefill primitive in its own
/// right. A thin [`ragged_forward`] builder: one segment, [`Emit::All`].
///
/// Returns one logits row per window token; row `j` equals what
/// `sess.step(tokens[j])` would have returned at that point, *bit for
/// bit* (see [`ragged_forward`] for why). The session is left having
/// consumed the whole window.
///
/// Any out-of-vocab token fails the call before any state is touched.
pub fn verify_window(sess: &mut DecoderSession, tokens: &[i32]) -> Result<Vec<Vec<f32>>> {
    if tokens.is_empty() {
        return Ok(Vec::new());
    }
    let segs = [SegmentSpec { tokens, emit: Emit::All }];
    let mut sessions: [&mut DecoderSession; 1] = [sess];
    let mut rows = ragged_forward(&mut sessions, &segs)?;
    Ok(rows.pop().expect("one segment"))
}

/// Advance many sessions by one token each with stacked compute — the
/// batched micro-step of the [`DecodeServer`] scheduler's baseline
/// (three-phase) mode, and the per-kind reference the planner tests
/// compare against. A thin [`ragged_forward`] builder: `B` one-token
/// segments, [`Emit::All`].
///
/// Row `i` of the result reproduces `sessions[i].step(tokens[i])`
/// *bit-for-bit* whatever the batch composition (see
/// [`ragged_forward`]), so micro-batch membership (and any spill/
/// restore in between) can never perturb a stream's logits.
///
/// All sessions must share one model (`Arc` identity); any invalid
/// token fails the whole call *before* any state is touched, so the
/// scheduler pre-validates and keeps singletons/out-of-vocab steps on
/// the scalar path.
pub fn step_many(
    sessions: &mut [&mut DecoderSession],
    tokens: &[i32],
) -> Result<Vec<Vec<f32>>> {
    let b = sessions.len();
    assert_eq!(tokens.len(), b, "one token per session");
    if b == 0 {
        return Ok(Vec::new());
    }
    let model = sessions[0].model.clone();
    if !sessions.iter().all(|s| Arc::ptr_eq(&s.model, &model)) {
        bail!("step_many requires sessions sharing one model");
    }
    let segs: Vec<SegmentSpec> = tokens
        .iter()
        .map(|t| SegmentSpec { tokens: std::slice::from_ref(t), emit: Emit::All })
        .collect();
    let rows = ragged_forward(sessions, &segs)?;
    Ok(rows
        .into_iter()
        .map(|mut r| r.pop().expect("one row per one-token segment"))
        .collect())
}

/// Exactness probe shared by the demos: stream `tokens` through a
/// fresh session and return the max |logit diff| against
/// `batch_logits` (the `forward_batch` output for the same tokens,
/// computed by the caller before the model moved into the server).
pub fn probe_exactness(
    client: &DecodeClient,
    batch_logits: &Tensor,
    tokens: &[i32],
) -> Result<f32> {
    let stream = client.open_stream()?;
    let mut max_diff = 0.0f32;
    for (t, &tok) in tokens.iter().enumerate() {
        let out = stream.step(tok)?;
        for (a, b) in out.logits.iter().zip(batch_logits.row(t)) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    Ok(max_diff)
}

/// Drive `sessions` concurrent greedy-decoding streams of `tokens`
/// tokens each through `client`, returning every token's latency in
/// seconds (demo/bench harness shared by the CLI and the example).
///
/// Thin wrapper over [`run_greedy_sessions_collect`] — all driving
/// logic lives there, once, so the two can never drift.
pub fn run_greedy_sessions(
    client: &DecodeClient,
    sessions: usize,
    tokens: usize,
    vocab: usize,
) -> Result<Vec<f64>> {
    run_greedy_sessions_collect(client, sessions, tokens, vocab).map(|(lats, _)| lats)
}

/// [`run_greedy_sessions`] that also returns each stream's greedy
/// (argmax) token sequence, in session launch order — the paging bench
/// and tests compare these across residency caps: prepacked kernels
/// make per-stream logits independent of micro-batch composition, so
/// the sequences must be *identical* however aggressively the server
/// spills.
pub fn run_greedy_sessions_collect(
    client: &DecodeClient,
    sessions: usize,
    tokens: usize,
    vocab: usize,
) -> Result<(Vec<f64>, Vec<Vec<i32>>)> {
    let handles: Vec<_> = (0..sessions)
        .map(|s| {
            let c = client.clone();
            std::thread::spawn(move || -> Result<(Vec<f64>, Vec<i32>)> {
                let stream = c.open_stream()?;
                let mut lats = Vec::with_capacity(tokens);
                let mut chosen = Vec::with_capacity(tokens);
                let mut tok = (s % vocab.max(1)) as i32;
                for _ in 0..tokens {
                    let out = stream.step(tok)?;
                    lats.push(out.latency.as_secs_f64());
                    tok = greedy_argmax(&out.logits);
                    chosen.push(tok);
                }
                Ok((lats, chosen))
            })
        })
        .collect();
    let mut lats = Vec::with_capacity(sessions * tokens);
    let mut streams = Vec::with_capacity(sessions);
    for h in handles {
        let (l, toks) = h.join().map_err(|_| anyhow!("session thread panicked"))??;
        lats.extend(l);
        streams.push(toks);
    }
    Ok((lats, streams))
}

/// Row-wise RMS normalization (no learned gain — reference model).
fn rms_norm(x: &Tensor) -> Tensor {
    let [m, n] = x.shape()[..] else { panic!("rms_norm needs 2-D") };
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let row = x.row(i);
        let ms = row.iter().map(|a| a * a).sum::<f32>() / n as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for (o, a) in out.data_mut()[i * n..(i + 1) * n].iter_mut().zip(row) {
            *o = a * inv;
        }
    }
    out
}

fn relu(t: Tensor) -> Tensor {
    t.map(|x| if x > 0.0 { x } else { 0.0 })
}

/// Copy `width` columns starting at `lo` into a fresh tensor.
fn slice_cols(t: &Tensor, lo: usize, width: usize) -> Tensor {
    let n = t.shape()[0];
    let mut out = Tensor::zeros(&[n, width]);
    for i in 0..n {
        out.data_mut()[i * width..(i + 1) * width]
            .copy_from_slice(&t.row(i)[lo..lo + width]);
    }
    out
}

/// Inverse of [`slice_cols`]: write `src` into columns starting at `lo`.
fn write_cols(dst: &mut Tensor, lo: usize, src: &Tensor) {
    let (n, width, cols) = (src.shape()[0], src.shape()[1], dst.shape()[1]);
    for i in 0..n {
        let drow = &mut dst.data_mut()[i * cols + lo..i * cols + lo + width];
        drow.copy_from_slice(src.row(i));
    }
}

// ---------------------------------------------------------------------------
// Streaming server
// ---------------------------------------------------------------------------

/// Scheduler tuning for the streaming decode server.
#[derive(Debug, Clone)]
pub struct DecodeServerConfig {
    /// Micro-batch fill window per scheduler wake-up.
    pub max_wait: Duration,
    /// Max steps drained per wake-up across all sessions.
    pub max_steps: usize,
    /// Rounds of at least this many distinct sessions take the batched
    /// [`step_many`] path; smaller rounds (singleton wake-ups) run the
    /// scalar `step`. `usize::MAX` disables batching entirely — the
    /// PR 1 scalar-loop scheduler, kept as the bench baseline.
    pub batch_threshold: usize,
    /// Residency cap: at most this many `DecoderSession`s live in RAM;
    /// the least-recently-stepped idle streams spill to the
    /// [`SessionStore`] and restore transparently on their next token.
    /// `0` means unlimited (every stream stays resident — the pre-paging
    /// behavior, and the default).
    pub max_resident_sessions: usize,
    /// Speculative decoding draft source ([`super::speculative`]).
    /// When not [`SpeculationConfig::Off`], streams opened through
    /// [`DecodeClient::open_stream`] run draft-propose / verify-accept
    /// lookahead; [`DecodeClient::open_stream_plain`] still opens plain
    /// streams alongside them. Speculation never changes a stream's
    /// tokens — greedy output stays bit-identical to a plain server.
    pub speculation: SpeculationConfig,
    /// Draft window K: tokens proposed (and verified as one stacked
    /// [`verify_window`] step) per speculative miss. `0` disables
    /// speculation regardless of `speculation`.
    pub draft_window: usize,
    /// Prompt tokens per stacked prefill pass ([`super::prefill`]):
    /// each pending prompt ingests in chunks of at most this many
    /// tokens, run as `C`-row prepacked GEMMs. Residency/spill
    /// interacts with a prefilling stream only at these boundaries.
    /// Clamped to ≥ 1.
    pub prefill_chunk: usize,
    /// Continuous-batching fairness knob: at most this many prompt
    /// tokens are ingested per scheduler round, so queued decode steps
    /// never wait behind more than one budget's worth of prefill work.
    /// `0` means no throttle (each round drains every pending prompt).
    pub prefill_budget: usize,
    /// Cost-aware companion to `prefill_budget`: at most this many
    /// wall-clock *milliseconds* of stacked prefill work per scheduler
    /// round, enforced through an EWMA of measured seconds-per-prompt-
    /// token ([`PrefillPacer`]). A token count mispredicts when per-
    /// token cost shifts with model size or thread count; the wall-time
    /// budget bounds decode latency directly. Whichever budget runs out
    /// first stops the round's prompt ingest. `0` disables the
    /// wall-time budget (the default). Ingest always makes progress: at
    /// least one prompt token is planned per round even when one token
    /// overruns the budget.
    pub prefill_budget_ms: f64,
    /// Drive decode steps, speculative verify windows, and prompt
    /// chunks through *one* stacked [`ragged_forward`] pass per wave —
    /// the unified ragged-batch planner (the default). `false` restores
    /// the three-phase scheduler (speculative steps in place, plain
    /// `step_many`, prefill after the decode rounds), kept as the bench
    /// baseline. Per-stream logits are bit-identical either way; only
    /// the pass shape changes.
    pub unified_planner: bool,
    /// Byte budget for the radix-tree prompt-prefix cache
    /// ([`super::prefix_cache`]). Prompted opens restore the deepest
    /// cached ancestor snapshot and prefill only the uncovered suffix;
    /// boundary snapshots are inserted at `prefix_snapshot_stride`
    /// token strides. `0` disables the cache (the default).
    pub prefix_cache_bytes: usize,
    /// Prompt-token stride at which prefill boundary snapshots are
    /// offered to the prefix cache (chunk boundaries whose token offset
    /// is a multiple of this). Smaller strides match more prefixes at
    /// the cost of more cached snapshots; `0` disables insertion (the
    /// cache can still serve whatever is already in it).
    pub prefix_snapshot_stride: usize,
    /// Telemetry wave-sampling knob ([`crate::telemetry`]): every N-th
    /// planned wave records its per-phase span durations, the
    /// rows-vs-latency ledger entry, and a `wave` flight-recorder
    /// event. `1` (the default) records every wave; `0` disables wave
    /// spans entirely. Counters and discrete events (open/close, shed,
    /// spill, deadline, …) are always on — they are the stats system of
    /// record. Telemetry is observation-only: token streams are
    /// bit-identical at any sampling rate
    /// (`benches/serve_telemetry.rs` enforces this).
    pub telemetry_sample: u64,
}

impl Default for DecodeServerConfig {
    fn default() -> Self {
        DecodeServerConfig {
            max_wait: Duration::from_millis(2),
            max_steps: 64,
            batch_threshold: 2,
            max_resident_sessions: 0,
            speculation: SpeculationConfig::Off,
            draft_window: 4,
            prefill_chunk: 32,
            prefill_budget: 256,
            prefill_budget_ms: 0.0,
            unified_planner: true,
            prefix_cache_bytes: 0,
            prefix_snapshot_stride: 64,
            telemetry_sample: 1,
        }
    }
}

/// One decoded token's output.
#[derive(Debug, Clone)]
pub struct StepOut {
    pub session: u64,
    /// 0-based position of the decoded token within its stream.
    pub pos: usize,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// How many steps rode the same scheduler wake-up (observability).
    pub micro_batch: usize,
}

/// Aggregate decode-server statistics.
///
/// Since the telemetry re-base this struct is a *read view*: the
/// scheduler writes `decode.*` metrics into the server's
/// [`Telemetry`] registry (the system of record), and
/// [`DecodeServer::stats`] rebuilds this struct from the registry by
/// name at read time. A field and its `snapshot()` document value can
/// therefore never drift apart (pinned by `tests/telemetry.rs`); the
/// shape and semantics of every field are unchanged.
#[derive(Debug, Default, Clone)]
pub struct DecodeStats {
    pub steps: usize,
    pub failed_steps: usize,
    pub micro_batches: usize,
    pub sessions_opened: usize,
    pub sessions_closed: usize,
    pub exec_secs: f64,
    /// Steps that rode a batched [`step_many`] round (vs scalar `step`).
    pub batched_steps: usize,
    /// Number of [`step_many`] invocations the scheduler issued.
    pub step_many_calls: usize,
    /// Sessions evicted to the [`SessionStore`] (residency manager).
    pub spills: usize,
    /// Sessions restored from the store on an incoming token.
    pub restores: usize,
    /// Peak resident `DecoderSession` count — stays at or below
    /// `max_resident_sessions` whenever a cap is set.
    pub resident_peak: usize,
    /// Cumulative snapshot bytes written to the store (each snapshot is
    /// framing + the session's `state_bytes()` payload).
    pub spilled_bytes: u64,
    /// Wall-clock seconds spent restoring spilled sessions.
    pub restore_secs: f64,
    /// Evictions that failed (snapshot or store write error). The
    /// victim stays resident rather than losing state, so a nonzero
    /// count means residency may exceed `max_resident_sessions` — the
    /// operator's signal that the spill store is unhealthy (e.g. disk
    /// full) before RAM growth becomes the symptom.
    pub spill_failures: usize,
    /// Draft tokens proposed to speculative verification.
    pub draft_proposed: usize,
    /// Draft tokens whose greedy verification matched (their logits
    /// became pre-verified lookahead).
    pub draft_accepted: usize,
    /// Stacked [`verify_window`] passes the speculative streams ran.
    pub verify_steps: usize,
    /// Speculative steps answered straight from verified lookahead
    /// (zero model compute on the step).
    pub lookahead_hits: usize,
    /// Prompts fully ingested through the chunked prefill path.
    pub prefills: usize,
    /// Prompts whose ingest failed (invalid restore mid-prompt, lost
    /// state) — the stream disconnects, the opener gets the error.
    pub failed_prefills: usize,
    /// Prompt tokens ingested via stacked prefill passes.
    pub prefill_tokens: usize,
    /// Stacked prefill passes run (each ≤ `prefill_chunk` tokens).
    pub prefill_chunks: usize,
    /// Cumulative time-to-first-token across completed prefills:
    /// admission (`open_stream_with_prompt` submit) → final-token
    /// logits delivered.
    pub ttft_secs: f64,
    /// Stacked [`ragged_forward`] passes the unified planner drove
    /// (each mixes any number of decode / verify / prefill segments).
    pub planned_rounds: usize,
    /// Single-token decode rows that rode a planned stacked pass.
    pub decode_rows: usize,
    /// Prompt-chunk rows that rode a planned stacked pass.
    pub prefill_rows: usize,
    /// Speculative verify-window rows that rode a planned stacked pass.
    pub verify_rows: usize,
    /// Smallest row count of any planned pass (0 until one runs).
    pub rows_per_pass_min: usize,
    /// Largest row count of any planned pass.
    pub rows_per_pass_max: usize,
    /// Steps cancelled at a wave boundary because their deadline had
    /// already passed (each also counts in `failed_steps`; the session
    /// itself does NOT advance, so the caller may retry the same token).
    pub deadline_expired_steps: usize,
    /// Queued prompt ingests cancelled because their deadline passed
    /// mid-queue (each also counts in `failed_prefills`; the stream
    /// disconnects — partial prompt state is never served).
    pub deadline_expired_prefills: usize,
    /// Prompted opens fully answered from the prefix cache (only the
    /// final prompt token ingested). Mirrors
    /// [`CacheStats`](super::prefix_cache::CacheStats) — these
    /// `prefix_*` fields are merged from the cache ledger at stats-read
    /// time, not accumulated by the scheduler.
    pub prefix_hits: usize,
    /// Prompted opens that restored a strict-ancestor snapshot and
    /// prefilled the remaining suffix.
    pub prefix_partial_hits: usize,
    /// Prompted opens (with the cache enabled) that matched nothing.
    pub prefix_misses: usize,
    /// Prompt tokens skipped by restoring cached snapshots — counted
    /// here and NOT in `prefill_tokens`, so the pacer/budget ledger
    /// stays a measure of work actually done.
    pub prefix_restored_tokens: usize,
    /// Bytes of snapshots currently resident in the prefix cache
    /// (≤ `prefix_cache_bytes` whenever a budget is set).
    pub prefix_bytes_resident: usize,
    /// Prefix-cache snapshots evicted under byte-budget pressure or
    /// dropped after a failed restore.
    pub prefix_evictions: usize,
    /// Boundary snapshots inserted into the prefix cache.
    pub prefix_insertions: usize,
    /// Snapshots currently resident in the prefix cache.
    pub prefix_snapshots: usize,
    /// Multilevel coarse-summary updates performed (merges up the
    /// binary counter plus compressions into the accumulator), drained
    /// from resident sessions at wave boundaries and before spills.
    /// Always 0 for depth-0 configs.
    pub ml_summary_updates: usize,
    /// Bytes of multilevel far-field summaries resident across all
    /// sessions at the last sync — the O(log n) share of decode state.
    /// Always 0 for depth-0 configs.
    pub ml_summary_bytes: usize,
    /// Per-tenant accounting for streams opened through the serve front
    /// tier (or any caller that tags opens with a tenant). Untagged
    /// traffic is not recorded here.
    pub per_tenant: HashMap<String, TenantLoad>,
}

/// Per-tenant slice of [`DecodeStats`] (see `per_tenant`).
#[derive(Debug, Default, Clone)]
pub struct TenantLoad {
    pub opened: usize,
    pub closed: usize,
    pub steps: usize,
    pub failed_steps: usize,
    /// Deadline-expired steps (subset of `failed_steps`).
    pub expired_steps: usize,
}

impl DecodeStats {
    pub fn mean_micro_batch(&self) -> f64 {
        if self.micro_batches == 0 {
            0.0
        } else {
            (self.steps + self.failed_steps) as f64 / self.micro_batches as f64
        }
    }

    /// Fraction of successful + failed steps that went through the
    /// batched path (observability for the batching criterion).
    pub fn batched_fraction(&self) -> f64 {
        let total = self.steps + self.failed_steps;
        if total == 0 {
            0.0
        } else {
            self.batched_steps as f64 / total as f64
        }
    }

    /// Mean sessions per `step_many` call (batched round width).
    pub fn mean_step_many_width(&self) -> f64 {
        if self.step_many_calls == 0 {
            0.0
        } else {
            self.batched_steps as f64 / self.step_many_calls as f64
        }
    }

    /// Mean seconds to restore one spilled session (0 if none restored).
    pub fn mean_restore_latency(&self) -> f64 {
        if self.restores == 0 {
            0.0
        } else {
            self.restore_secs / self.restores as f64
        }
    }

    /// Fraction of proposed draft tokens that survived greedy
    /// verification (0 when nothing was proposed).
    pub fn accept_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Mean time-to-first-token over completed prefills (0 if none).
    pub fn mean_ttft(&self) -> f64 {
        if self.prefills == 0 {
            0.0
        } else {
            self.ttft_secs / self.prefills as f64
        }
    }

    /// Mean rows per planned stacked pass (0 until one runs) — the
    /// planner's effective batch width across all traffic kinds.
    pub fn mean_rows_per_pass(&self) -> f64 {
        if self.planned_rounds == 0 {
            0.0
        } else {
            (self.decode_rows + self.prefill_rows + self.verify_rows) as f64
                / self.planned_rounds as f64
        }
    }
}

enum DecodeMsg {
    Open {
        session: u64,
        /// `None`: the server default (speculative iff the server has a
        /// draft source). `Some(b)`: the client forced plain/speculative.
        speculative: Option<bool>,
        /// Tenant tag for per-tenant stats (front-tier traffic).
        tenant: Option<Arc<str>>,
        /// Client-chosen trace id threaded onto every flight-recorder
        /// event this stream emits (0 = untraced).
        trace: u64,
        reply: Sender<Result<()>>,
    },
    /// Admit a stream with a pending prompt: the session registers
    /// immediately, the prompt ingests in chunked stacked passes
    /// interleaved with decode rounds, and the reply delivers the final
    /// prompt token's logits once ingest completes (or the admission /
    /// ingest error).
    OpenWithPrompt {
        session: u64,
        speculative: Option<bool>,
        tenant: Option<Arc<str>>,
        trace: u64,
        /// Ingest budget: if the whole prompt has not completed by this
        /// instant, the pending ingest is cancelled at the next wave
        /// boundary with a typed "deadline expired" error.
        deadline: Option<Instant>,
        prompt: Vec<i32>,
        submitted: Instant,
        reply: Sender<Result<PrefillOut>>,
    },
    Step(StepReq),
    Close { session: u64 },
    Shutdown,
}

struct StepReq {
    session: u64,
    token: i32,
    submitted: Instant,
    /// Expired steps are cancelled (typed error) at the next wave
    /// boundary instead of silently completing late; the session does
    /// not advance.
    deadline: Option<Instant>,
    tenant: Option<Arc<str>>,
    reply: Sender<Result<StepOut>>,
}

/// Default bound on every blocking client wait ([`DecodeClient`],
/// [`DecodeStream::step`], [`super::Client::infer`]): a wedged
/// scheduler thread surfaces as a typed "timed out" error instead of
/// hanging the caller forever. Override per-client with
/// `with_recv_timeout`.
pub const DEFAULT_CLIENT_RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// Bounded reply wait: `Timeout` becomes a typed "timed out" error,
/// `Disconnected` keeps the historical "shut down"-style message so
/// existing failure-envelope expectations hold.
fn recv_reply<T>(rx: &Receiver<T>, timeout: Duration, what: &str) -> Result<T> {
    match rx.recv_timeout(timeout) {
        Ok(v) => Ok(v),
        Err(RecvTimeoutError::Timeout) => Err(anyhow!(
            "decode client timed out after {timeout:?} waiting for {what} reply \
             (scheduler wedged or overloaded)"
        )),
        Err(RecvTimeoutError::Disconnected) => {
            Err(anyhow!("decode server shut down during {what}"))
        }
    }
}

/// Per-open knobs for [`DecodeClient::open_stream_opts`] /
/// [`DecodeClient::open_stream_with_prompt_opts`] — the front tier's
/// hook for tenancy and deadline propagation. `Default` matches the
/// plain `open_stream*` helpers: server-default stream kind, untagged,
/// no deadline.
#[derive(Debug, Clone, Default)]
pub struct OpenOptions {
    /// `None`: server default; `Some(b)`: force plain/speculative.
    pub speculative: Option<bool>,
    /// Tenant tag: opens/steps/closes on the stream are attributed to
    /// this tenant in [`DecodeStats::per_tenant`].
    pub tenant: Option<Arc<str>>,
    /// Prompt-ingest deadline (prompted opens only): ingest still
    /// pending at this instant is cancelled at the next wave boundary.
    pub deadline: Option<Instant>,
    /// Flight-recorder trace id: every telemetry event this stream
    /// emits (open/close, spill/restore, deadline, prefix outcome)
    /// carries this id, threaded from the FMMW `open` frame. `0` (the
    /// default) means untraced; events still record, tagged 0.
    pub trace: u64,
}

/// Handle for opening decode streams; cloneable across client threads.
#[derive(Clone)]
pub struct DecodeClient {
    tx: Sender<DecodeMsg>,
    next_id: Arc<AtomicU64>,
    /// Live prefill-queue depth (streams with pending prompt tokens),
    /// published by the scheduler each round — the front tier's
    /// backpressure signal for shedding prompted opens.
    queue_depth: Arc<AtomicUsize>,
    recv_timeout: Duration,
}

impl DecodeClient {
    /// Register a fresh session server-side and return its stream —
    /// speculative when the server config enables speculation, plain
    /// otherwise (the server default).
    pub fn open_stream(&self) -> Result<DecodeStream> {
        self.open_with(None)
    }

    /// Open a stream that decodes plainly even on a speculative server
    /// (speculative and plain streams share one scheduler).
    pub fn open_stream_plain(&self) -> Result<DecodeStream> {
        self.open_with(Some(false))
    }

    /// Open a speculative stream explicitly; errors if the server has
    /// no draft source configured.
    pub fn open_stream_speculative(&self) -> Result<DecodeStream> {
        self.open_with(Some(true))
    }

    /// Open with explicit [`OpenOptions`] (tenant tag; the deadline
    /// field is ignored for unprompted opens — admission is immediate).
    pub fn open_stream_opts(&self, opts: OpenOptions) -> Result<DecodeStream> {
        let session = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let tenant = opts.tenant.clone();
        self.tx
            .send(DecodeMsg::Open {
                session,
                speculative: opts.speculative,
                tenant: opts.tenant,
                trace: opts.trace,
                reply,
            })
            .map_err(|_| anyhow!("decode server shut down: cannot open stream"))?;
        recv_reply(&rx, self.recv_timeout, "open")??;
        Ok(DecodeStream {
            session,
            tx: self.tx.clone(),
            tenant,
            recv_timeout: self.recv_timeout,
        })
    }

    fn open_with(&self, speculative: Option<bool>) -> Result<DecodeStream> {
        self.open_stream_opts(OpenOptions { speculative, ..OpenOptions::default() })
    }

    /// Clone of this handle whose blocking waits (open / prefill /
    /// step replies) give up after `timeout` with a typed "timed out"
    /// error. Streams opened through it inherit the bound.
    pub fn with_recv_timeout(mut self, timeout: Duration) -> DecodeClient {
        self.recv_timeout = timeout;
        self
    }

    /// Streams currently queued for prompt ingest (scheduler-published,
    /// one round stale at most) — the load-shedding signal: reject new
    /// prompted opens when this exceeds the operator's queue bound.
    pub fn prefill_queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// Open a stream pre-loaded with `prompt`: the prompt ingests
    /// server-side in chunked stacked passes ([`super::prefill`]) at
    /// GEMM throughput — not N scalar steps — interleaved with other
    /// streams' decode rounds under the server's prefill budget. Blocks
    /// until ingest completes and returns the stream (positioned after
    /// the whole prompt) plus the final prompt token's logits; feed
    /// `greedy_argmax(&out.logits)` to [`DecodeStream::step`] to start
    /// decoding. The stream kind follows the server default (cf.
    /// [`open_stream`](Self::open_stream)).
    pub fn open_stream_with_prompt(
        &self,
        prompt: &[i32],
    ) -> Result<(DecodeStream, PrefillOut)> {
        self.open_with_prompt(None, prompt)
    }

    /// Prompted open that decodes plainly even on a speculative server.
    pub fn open_stream_with_prompt_plain(
        &self,
        prompt: &[i32],
    ) -> Result<(DecodeStream, PrefillOut)> {
        self.open_with_prompt(Some(false), prompt)
    }

    /// Prompted open of an explicitly speculative stream; errors if the
    /// server has no draft source configured. The draft source is
    /// primed with the prompt history during ingest, so drafts can
    /// propose (and verify) from the very first generated token.
    pub fn open_stream_with_prompt_speculative(
        &self,
        prompt: &[i32],
    ) -> Result<(DecodeStream, PrefillOut)> {
        self.open_with_prompt(Some(true), prompt)
    }

    /// Prompted open with explicit [`OpenOptions`]: tenant tag plus an
    /// optional ingest deadline — if the prompt has not fully ingested
    /// by `opts.deadline`, the pending ingest is cancelled at the next
    /// wave boundary and this returns a typed "deadline expired" error.
    pub fn open_stream_with_prompt_opts(
        &self,
        prompt: &[i32],
        opts: OpenOptions,
    ) -> Result<(DecodeStream, PrefillOut)> {
        let session = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let tenant = opts.tenant.clone();
        self.tx
            .send(DecodeMsg::OpenWithPrompt {
                session,
                speculative: opts.speculative,
                tenant: opts.tenant,
                trace: opts.trace,
                deadline: opts.deadline,
                prompt: prompt.to_vec(),
                submitted: Instant::now(),
                reply,
            })
            .map_err(|_| anyhow!("decode server shut down: cannot open stream"))?;
        let out = recv_reply(&rx, self.recv_timeout, "prefill")??;
        Ok((
            DecodeStream {
                session,
                tx: self.tx.clone(),
                tenant,
                recv_timeout: self.recv_timeout,
            },
            out,
        ))
    }

    fn open_with_prompt(
        &self,
        speculative: Option<bool>,
        prompt: &[i32],
    ) -> Result<(DecodeStream, PrefillOut)> {
        self.open_stream_with_prompt_opts(
            prompt,
            OpenOptions { speculative, ..OpenOptions::default() },
        )
    }
}

/// One open autoregressive stream. Steps are processed in submission
/// order; `step_async` pipelines without waiting. Dropping the stream
/// closes the session server-side (best effort).
pub struct DecodeStream {
    session: u64,
    tx: Sender<DecodeMsg>,
    tenant: Option<Arc<str>>,
    recv_timeout: Duration,
}

impl DecodeStream {
    pub fn id(&self) -> u64 {
        self.session
    }

    /// Submit one token; returns a receiver for its logits.
    pub fn step_async(&self, token: i32) -> Result<Receiver<Result<StepOut>>> {
        self.step_async_with_deadline(token, None)
    }

    /// `step_async` carrying an explicit deadline: if the step is still
    /// queued when the deadline passes, the scheduler cancels it at the
    /// next wave boundary with a typed "deadline expired" error — the
    /// session does not advance, so the same token may be resubmitted.
    pub fn step_async_with_deadline(
        &self,
        token: i32,
        deadline: Option<Instant>,
    ) -> Result<Receiver<Result<StepOut>>> {
        let (reply, rx) = mpsc::channel();
        let req = StepReq {
            session: self.session,
            token,
            submitted: Instant::now(),
            deadline,
            tenant: self.tenant.clone(),
            reply,
        };
        self.tx
            .send(DecodeMsg::Step(req))
            .map_err(|_| anyhow!("decode server shut down: step not accepted"))?;
        Ok(rx)
    }

    /// Submit one token and wait for its logits (bounded by the
    /// client's recv timeout — a wedged scheduler cannot hang us).
    pub fn step(&self, token: i32) -> Result<StepOut> {
        self.step_with_deadline(token, None)
    }

    /// Blocking step with a deadline (see `step_async_with_deadline`).
    pub fn step_with_deadline(
        &self,
        token: i32,
        deadline: Option<Instant>,
    ) -> Result<StepOut> {
        let rx = self.step_async_with_deadline(token, deadline)?;
        recv_reply(&rx, self.recv_timeout, "step")?
    }
}

impl Drop for DecodeStream {
    fn drop(&mut self) {
        self.tx.send(DecodeMsg::Close { session: self.session }).ok();
    }
}

/// The streaming decode server: owns the model and all session state on
/// a single scheduler thread (host compute is CPU-bound; one thread is
/// the honest design, mirroring [`super::Server`]).
pub struct DecodeServer {
    client: Option<DecodeClient>,
    tele: Arc<Telemetry>,
    cache: Arc<Mutex<PrefixCache>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl DecodeServer {
    /// Start with the default heap-backed [`MemStore`] (only consulted
    /// when `cfg.max_resident_sessions` caps residency).
    pub fn start(model: HostDecoder, cfg: DecodeServerConfig) -> DecodeServer {
        DecodeServer::start_with_store(model, cfg, Box::new(MemStore::new()))
    }

    /// Start with an explicit spill store (e.g.
    /// [`session_store::DiskStore`](crate::serve::session_store::DiskStore)
    /// so idle streams cost zero RAM). Builds a fresh [`Telemetry`]
    /// (real clock, `cfg.telemetry_sample`).
    pub fn start_with_store(
        model: HostDecoder,
        cfg: DecodeServerConfig,
        store: Box<dyn SessionStore>,
    ) -> DecodeServer {
        let tele = Telemetry::new(cfg.telemetry_sample);
        DecodeServer::start_with_store_telemetry(model, cfg, store, tele)
    }

    /// Start against a caller-supplied [`Telemetry`] — the front tier
    /// hands each engine generation a [`Telemetry::child`] so stats
    /// registries stay per-generation while one shared flight recorder
    /// (and clock) sees the whole story; chaos tests hand in a
    /// mock-clock instance.
    pub fn start_with_store_telemetry(
        model: HostDecoder,
        cfg: DecodeServerConfig,
        store: Box<dyn SessionStore>,
        tele: Arc<Telemetry>,
    ) -> DecodeServer {
        let (tx, rx) = mpsc::channel::<DecodeMsg>();
        let tele_thread = tele.clone();
        let queue_depth = Arc::new(AtomicUsize::new(0));
        let depth_thread = queue_depth.clone();
        let cache = Arc::new(Mutex::new(PrefixCache::new(cfg.prefix_cache_bytes)));
        let cache_thread = cache.clone();
        let model = Arc::new(model);
        let handle = std::thread::Builder::new()
            .name("fmm-decode".into())
            .spawn(move || {
                decode_scheduler(
                    model,
                    cfg,
                    store,
                    rx,
                    tele_thread,
                    depth_thread,
                    cache_thread,
                )
            })
            .expect("spawn decode scheduler");
        DecodeServer {
            client: Some(DecodeClient {
                tx,
                next_id: Arc::new(AtomicU64::new(0)),
                queue_depth,
                recv_timeout: DEFAULT_CLIENT_RECV_TIMEOUT,
            }),
            tele,
            cache,
            handle: Some(handle),
        }
    }

    pub fn client(&self) -> DecodeClient {
        self.client.as_ref().expect("server running").clone()
    }

    /// This server's telemetry bundle (registry + flight recorder +
    /// clock) — the system of record [`stats`](Self::stats) reads from.
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.tele.clone()
    }

    pub fn stats(&self) -> DecodeStats {
        stats_view(&self.tele, &self.cache)
    }

    /// The prompt-prefix cache (inert when `prefix_cache_bytes` was 0).
    /// Tests and chaos tooling reach through this to inspect residency
    /// or poison cached snapshots; the scheduler shares the same
    /// instance.
    pub fn prefix_cache(&self) -> Arc<Mutex<PrefixCache>> {
        self.cache.clone()
    }

    /// Graceful shutdown via the explicit sentinel: queued steps are
    /// served first; live clients/streams never deadlock the join and
    /// see clean errors on later use.
    pub fn shutdown(mut self) -> DecodeStats {
        if let Some(c) = self.client.take() {
            c.tx.send(DecodeMsg::Shutdown).ok();
        }
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
        stats_view(&self.tele, &self.cache)
    }
}

/// Resolve the per-tenant counter `decode.tenant.<tenant>.<field>`.
/// Tenant names may themselves contain dots; the read view splits at
/// the *last* dot, so the family stays parseable either way.
fn tenant_counter(
    r: &Registry,
    tenant: &str,
    field: &str,
) -> Arc<crate::telemetry::Counter> {
    r.counter(&format!("decode.tenant.{tenant}.{field}"))
}

/// Sync the prefix-cache ledger (the single source of truth for the
/// `prefix_*` numbers) into the registry as gauges, so the snapshot
/// document and the [`DecodeStats`] read view agree at any read point.
fn sync_prefix_gauges(tele: &Telemetry, cache: &Mutex<PrefixCache>) {
    let c = lock_cache(cache).stats();
    let r = tele.registry();
    r.gauge("decode.prefix_hits").set(c.hits as u64);
    r.gauge("decode.prefix_partial_hits").set(c.partial_hits as u64);
    r.gauge("decode.prefix_misses").set(c.misses as u64);
    r.gauge("decode.prefix_restored_tokens").set(c.restored_tokens as u64);
    r.gauge("decode.prefix_bytes_resident").set(c.bytes_resident as u64);
    r.gauge("decode.prefix_evictions").set(c.evictions as u64);
    r.gauge("decode.prefix_insertions").set(c.insertions as u64);
    r.gauge("decode.prefix_snapshots").set(c.snapshots as u64);
}

/// Rebuild the legacy [`DecodeStats`] struct from the registry by name
/// — the read view that keeps every existing caller (benches, tests,
/// the front tier's stats document) working unchanged on top of the
/// telemetry re-base. Absent names read as zero, so a fresh server
/// yields `DecodeStats::default()`.
fn stats_view(tele: &Telemetry, cache: &Mutex<PrefixCache>) -> DecodeStats {
    sync_prefix_gauges(tele, cache);
    let r = tele.registry();
    let c = |name: &str| r.counter_value(name) as usize;
    let g = |name: &str| r.gauge_value(name) as usize;
    let mut s = DecodeStats {
        steps: c("decode.steps"),
        failed_steps: c("decode.failed_steps"),
        micro_batches: c("decode.micro_batches"),
        sessions_opened: c("decode.sessions_opened"),
        sessions_closed: c("decode.sessions_closed"),
        exec_secs: r.float_value("decode.exec_secs"),
        batched_steps: c("decode.batched_steps"),
        step_many_calls: c("decode.step_many_calls"),
        spills: g("decode.spills"),
        restores: g("decode.restores"),
        resident_peak: g("decode.resident_peak"),
        spilled_bytes: r.gauge_value("decode.spilled_bytes"),
        restore_secs: r.float_value("decode.restore_secs"),
        spill_failures: g("decode.spill_failures"),
        draft_proposed: c("decode.draft_proposed"),
        draft_accepted: c("decode.draft_accepted"),
        verify_steps: c("decode.verify_steps"),
        lookahead_hits: c("decode.lookahead_hits"),
        prefills: c("decode.prefills"),
        failed_prefills: c("decode.failed_prefills"),
        prefill_tokens: c("decode.prefill_tokens"),
        prefill_chunks: c("decode.prefill_chunks"),
        ttft_secs: r.float_value("decode.ttft_secs"),
        planned_rounds: c("decode.planned_rounds"),
        decode_rows: c("decode.decode_rows"),
        prefill_rows: c("decode.prefill_rows"),
        verify_rows: c("decode.verify_rows"),
        rows_per_pass_min: g("decode.rows_per_pass_min"),
        rows_per_pass_max: g("decode.rows_per_pass_max"),
        deadline_expired_steps: c("decode.deadline_expired_steps"),
        deadline_expired_prefills: c("decode.deadline_expired_prefills"),
        prefix_hits: g("decode.prefix_hits"),
        prefix_partial_hits: g("decode.prefix_partial_hits"),
        prefix_misses: g("decode.prefix_misses"),
        prefix_restored_tokens: g("decode.prefix_restored_tokens"),
        prefix_bytes_resident: g("decode.prefix_bytes_resident"),
        prefix_evictions: g("decode.prefix_evictions"),
        prefix_insertions: g("decode.prefix_insertions"),
        prefix_snapshots: g("decode.prefix_snapshots"),
        ml_summary_updates: c("decode.ml_summary_updates"),
        ml_summary_bytes: g("decode.ml_summary_bytes"),
        per_tenant: HashMap::new(),
    };
    for name in r.names_with_prefix("decode.tenant.") {
        let rest = &name["decode.tenant.".len()..];
        let Some(dot) = rest.rfind('.') else { continue };
        let (tenant, field) = (&rest[..dot], &rest[dot + 1..]);
        let v = r.counter_value(&name) as usize;
        let t = s.per_tenant.entry(tenant.to_string()).or_default();
        match field {
            "opened" => t.opened = v,
            "closed" => t.closed = v,
            "steps" => t.steps = v,
            "failed_steps" => t.failed_steps = v,
            "expired_steps" => t.expired_steps = v,
            _ => {}
        }
    }
    s
}

/// Poison-tolerant prefix-cache lock: the cache's invariants are
/// enforced per-call, so a panic while the lock was held leaves (at
/// worst) stale counters — better than turning every later prompted
/// open into a panic.
fn lock_cache(cache: &Mutex<PrefixCache>) -> MutexGuard<'_, PrefixCache> {
    cache.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One resident stream: plain incremental decode, or the speculative
/// draft/verify wrapper around the same session type. Both spill
/// through the same snapshot path; a speculative slot first rewinds to
/// its committed boundary so a snapshot never captures mid-speculation
/// state.
enum Slot {
    Plain(DecoderSession),
    Spec(SpeculativeSession),
}

impl Slot {
    /// Snapshot for spilling (committed boundary for speculative slots).
    fn snapshot(&mut self) -> Result<Vec<u8>> {
        match self {
            Slot::Plain(sess) => sess.snapshot(),
            Slot::Spec(spec) => spec.snapshot_committed(),
        }
    }

    /// The underlying decode session (the committed one for speculative
    /// slots) — the multilevel telemetry sync reads summary meters
    /// through here.
    fn session_mut(&mut self) -> &mut DecoderSession {
        match self {
            Slot::Plain(sess) => sess,
            Slot::Spec(spec) => spec.session_mut(),
        }
    }
}

/// Session residency manager — the scheduler half of cross-request
/// paging. At most `cap` [`DecoderSession`]s live in RAM; everything
/// else waits in the [`SessionStore`] as a snapshot blob and is
/// restored transparently when its stream's next token arrives. LRU
/// order is kept by a monotone step clock; eviction is driven by the
/// micro-batch loop (a batch's own sessions are pinned while it runs,
/// and waves are at most `cap` wide, so residency never overshoots the
/// cap). Also owns the speculative stream factory: which streams are
/// speculative is remembered in `spec_ids`, so a spilled speculative
/// stream restores back into its draft/verify wrapper (with a fresh
/// draft source — lookahead is recomputed, tokens are unaffected).
struct Residency {
    resident: HashMap<u64, Slot>,
    store: Box<dyn SessionStore>,
    /// Draft machinery shared by every speculative stream, or the
    /// startup error explaining why speculative opens must fail
    /// (`Ok(None)` = speculation off).
    spec: std::result::Result<Option<SpecFactory>, String>,
    /// Streams opened speculative (survives their spills).
    spec_ids: HashSet<u64>,
    /// Tenant tags for per-tenant stat attribution (survives spills;
    /// untagged streams have no entry).
    tenants: HashMap<u64, Arc<str>>,
    /// Client-chosen trace ids tagging this stream's flight-recorder
    /// events (survives spills; untraced streams have no entry).
    traces: HashMap<u64, u64>,
    /// Telemetry sink for spill/restore/fault events and the residency
    /// gauges.
    tele: Arc<Telemetry>,
    /// Effective cap (`usize::MAX` when the config said unlimited).
    cap: usize,
    /// Monotone clock: bumped whenever a session is opened, restored or
    /// stepped; the smallest stamp is the LRU eviction victim.
    tick: u64,
    last_used: HashMap<u64, u64>,
    peak: usize,
    spills: usize,
    restores: usize,
    spilled_bytes: u64,
    restore_secs: f64,
    spill_failures: usize,
}

impl Residency {
    fn new(
        store: Box<dyn SessionStore>,
        max_resident: usize,
        spec: std::result::Result<Option<SpecFactory>, String>,
        tele: Arc<Telemetry>,
    ) -> Residency {
        Residency {
            resident: HashMap::new(),
            store,
            spec,
            spec_ids: HashSet::new(),
            tenants: HashMap::new(),
            traces: HashMap::new(),
            tele,
            cap: if max_resident == 0 { usize::MAX } else { max_resident },
            tick: 0,
            last_used: HashMap::new(),
            peak: 0,
            spills: 0,
            restores: 0,
            spilled_bytes: 0,
            restore_secs: 0.0,
            spill_failures: 0,
        }
    }

    fn touch(&mut self, id: u64) {
        self.tick += 1;
        self.last_used.insert(id, self.tick);
    }

    /// Register a freshly opened stream, spilling an idle one first if
    /// the table is at the cap. Only the new id is pinned — a victim
    /// with a step already queued in this window just restores inside
    /// its wave. (Pinning every queued-step session instead would save
    /// that round-trip but lets residency overshoot the cap whenever
    /// all residents have queued steps; the cap is the RAM contract,
    /// so it wins.)
    ///
    /// `speculative`: `None` takes the server default (speculative iff
    /// a draft source is configured); `Some(b)` forces the kind. `Err`
    /// when a speculative stream is requested (or defaulted) while the
    /// draft source is unavailable — the stream is not registered.
    fn open(
        &mut self,
        id: u64,
        model: &Arc<HostDecoder>,
        speculative: Option<bool>,
    ) -> Result<()> {
        let sess = DecoderSession::new(model.clone());
        let slot = match (speculative, &self.spec) {
            (Some(false), _) | (None, Ok(None)) => Slot::Plain(sess),
            (Some(true), Ok(None)) => {
                bail!(
                    "speculation is disabled on this server \
                     (speculation mode Off, or draft_window 0)"
                )
            }
            (_, Ok(Some(factory))) => {
                self.spec_ids.insert(id);
                Slot::Spec(factory.wrap(sess))
            }
            (_, Err(msg)) => bail!("speculative draft source unavailable: {msg}"),
        };
        self.make_room(&[id]);
        self.resident.insert(id, slot);
        self.peak = self.peak.max(self.resident.len());
        self.touch(id);
        Ok(())
    }

    /// Drop a stream wherever it lives; true if it existed. Both homes
    /// are cleared unconditionally (no short-circuit): if a fault ever
    /// leaves a session resident *and* with a stale store snapshot, the
    /// spill blob — a disk file under `DiskStore` — is still deleted
    /// here rather than leaking until server drop.
    fn close(&mut self, id: u64) -> bool {
        self.last_used.remove(&id);
        self.spec_ids.remove(&id);
        self.tenants.remove(&id);
        self.traces.remove(&id);
        let was_resident = self.resident.remove(&id).is_some();
        let was_spilled = self.store.remove(id);
        was_resident || was_spilled
    }

    /// Tenant tag of a stream, if it was opened with one.
    fn tenant_of(&self, id: u64) -> Option<Arc<str>> {
        self.tenants.get(&id).cloned()
    }

    /// Trace id of a stream (0 when untraced or unknown).
    fn trace_of(&self, id: u64) -> u64 {
        self.traces.get(&id).copied().unwrap_or(0)
    }

    /// Record a flight-recorder event attributed to stream `id`,
    /// carrying its tenant tag and trace id.
    fn stream_event(&self, kind: EventKind, id: u64, detail: &str, a: u64, b: u64) {
        let tenant = self.tenants.get(&id).map(|t| t.as_ref()).unwrap_or("");
        self.tele.event(kind, id, tenant, self.trace_of(id), detail, a, b);
    }

    /// Spill least-recently-used sessions not in `pinned` until there
    /// is room to insert one more. Stops early (leaving the table over
    /// the cap) only if every resident session is pinned or a spill
    /// fails — state is never discarded to make room; failed spills
    /// count in `spill_failures` so an unhealthy store is visible
    /// before unbounded residency is.
    fn make_room(&mut self, pinned: &[u64]) {
        while self.resident.len() >= self.cap {
            let victim = self
                .resident
                .keys()
                .copied()
                .filter(|id| !pinned.contains(id))
                .min_by_key(|id| self.last_used.get(id).copied().unwrap_or(0));
            let Some(victim) = victim else { return };
            // Drain the victim's pending summary-update counts into the
            // registry before its state leaves RAM — the work meter
            // survives the spill (the counts are not serialized).
            if let Some(slot) = self.resident.get_mut(&victim) {
                let drained = slot.session_mut().drain_summary_updates();
                if drained > 0 {
                    self.tele.registry().counter("decode.ml_summary_updates").add(drained);
                }
            }
            // Snapshot wants `&mut`: a speculative victim rewinds to its
            // committed boundary first (lookahead is never spilled).
            let snap = match self.resident.get_mut(&victim).map(|s| s.snapshot()) {
                Some(Ok(snap)) => snap,
                _ => {
                    self.spill_failures += 1;
                    self.stream_event(EventKind::SpillFault, victim, "snapshot", 0, 0);
                    return;
                }
            };
            if self.store.put(victim, &snap).is_err() {
                self.spill_failures += 1;
                self.stream_event(EventKind::SpillFault, victim, "store_put", 0, 0);
                return;
            }
            self.resident.remove(&victim);
            self.spills += 1;
            self.spilled_bytes += snap.len() as u64;
            self.stream_event(EventKind::Spill, victim, "", snap.len() as u64, 0);
        }
    }

    /// Make `id` resident if it is currently spilled. `Ok(true)`: the
    /// session is in the table now; `Ok(false)`: unknown (never opened,
    /// or closed). `Err`: a snapshot existed but could not be read or
    /// decoded — that stream's state is gone and only it disconnects.
    fn ensure_resident(
        &mut self,
        id: u64,
        model: &Arc<HostDecoder>,
        pinned: &[u64],
    ) -> Result<bool> {
        if self.resident.contains_key(&id) {
            return Ok(true);
        }
        let snap = match self.store.take(id) {
            Ok(Some(snap)) => snap,
            Ok(None) => return Ok(false),
            Err(e) => {
                self.stream_event(EventKind::SpillFault, id, "store_take", 0, 0);
                return Err(e);
            }
        };
        let t0 = Instant::now();
        let slot = match self.rebuild_slot(id, model, &snap) {
            Ok(slot) => slot,
            Err(e) => {
                self.stream_event(EventKind::SpillFault, id, "restore_decode", 0, 0);
                return Err(e);
            }
        };
        self.make_room(pinned);
        self.resident.insert(id, slot);
        self.restores += 1;
        let restore_s = t0.elapsed().as_secs_f64();
        self.restore_secs += restore_s;
        self.stream_event(EventKind::Restore, id, "", (restore_s * 1e6) as u64, 0);
        self.peak = self.peak.max(self.resident.len());
        self.touch(id);
        Ok(true)
    }

    /// Decode a snapshot blob into the right [`Slot`] kind for `id`.
    /// A speculative stream re-wraps with a fresh draft source, primed
    /// from the snapshot's draft-history leaf when one rode along —
    /// so a spilled or prefix-cache-forked speculative stream proposes
    /// from its first post-restore token instead of re-warming.
    fn rebuild_slot(
        &self,
        id: u64,
        model: &Arc<HostDecoder>,
        snap: &[u8],
    ) -> Result<Slot> {
        let (sess, draft) = DecoderSession::restore_with_draft(model.clone(), snap)?;
        Ok(match (self.spec_ids.contains(&id), &self.spec) {
            (true, Ok(Some(factory))) => {
                let mut spec = factory.wrap(sess);
                if let Some(history) = draft {
                    spec.prime_draft(&history);
                }
                Slot::Spec(spec)
            }
            _ => Slot::Plain(sess),
        })
    }

    /// Replace `id`'s resident state with a decoded snapshot — the
    /// prefix-cache fork path. The stream keeps its slot kind (a
    /// speculative open re-wraps and primes its draft from the cached
    /// history). On `Err` the previously registered state is untouched,
    /// so the caller simply falls back to a cold prefill.
    fn adopt_snapshot(
        &mut self,
        id: u64,
        model: &Arc<HostDecoder>,
        snap: &[u8],
    ) -> Result<()> {
        let slot = self.rebuild_slot(id, model, snap)?;
        self.resident.insert(id, slot);
        self.touch(id);
        Ok(())
    }

    /// Publish the residency counters into the registry (they are
    /// cumulative here, so the registry side is gauges that get *set*,
    /// never added — exactly the overwrite semantics the legacy
    /// `sync_stats` had).
    fn sync_gauges(&self) {
        let r = self.tele.registry();
        r.gauge("decode.spills").set(self.spills as u64);
        r.gauge("decode.restores").set(self.restores as u64);
        r.gauge("decode.resident_peak").set(self.peak as u64);
        r.gauge("decode.spilled_bytes").set(self.spilled_bytes);
        r.float("decode.restore_secs").set(self.restore_secs);
        r.gauge("decode.spill_failures").set(self.spill_failures as u64);
    }

    /// Drain multilevel summary meters from every resident session into
    /// the registry: the update counter accumulates (work performed,
    /// exactly once per merge/compress), the bytes gauge is overwritten
    /// (current residency). Runs at wave boundaries next to
    /// [`sync_gauges`](Self::sync_gauges). Both metrics are published
    /// unconditionally so depth-0 servers pin them at 0 — the telemetry
    /// drift test relies on the names existing either way.
    fn sync_ml(&mut self) {
        let mut drained = 0u64;
        let mut bytes = 0usize;
        for slot in self.resident.values_mut() {
            let sess = slot.session_mut();
            drained += sess.drain_summary_updates();
            bytes += sess.summary_bytes();
        }
        let r = self.tele.registry();
        r.counter("decode.ml_summary_updates").add(drained);
        r.gauge("decode.ml_summary_bytes").set(bytes as u64);
    }
}

#[allow(clippy::too_many_arguments)]
fn decode_scheduler(
    model: Arc<HostDecoder>,
    cfg: DecodeServerConfig,
    store: Box<dyn SessionStore>,
    rx: Receiver<DecodeMsg>,
    tele: Arc<Telemetry>,
    queue_depth: Arc<AtomicUsize>,
    cache: Arc<Mutex<PrefixCache>>,
) {
    // Build the draft machinery once; a failed build (bad draft model
    // config) fails speculative opens with its message, while plain
    // streams keep serving.
    let spec = SpecFactory::build(&cfg, model.config()).map_err(|e| format!("{e:#}"));
    let mut res = Residency::new(store, cfg.max_resident_sessions, spec, tele.clone());
    let mut prefills = PrefillQueue::new(cfg.prefill_chunk);
    // The pacer's cost model (EWMA seconds-per-prompt-token) persists
    // across rounds; only its per-round spend resets.
    let mut pacer = PrefillPacer::new(cfg.prefill_budget_ms);
    // Boundary snapshots feed the cache only when it can hold them.
    let stride =
        if cfg.prefix_cache_bytes == 0 { 0 } else { cfg.prefix_snapshot_stride };
    loop {
        let mut steps: Vec<StepReq> = Vec::new();
        let mut closes: Vec<u64> = Vec::new();
        let mut exit = false;

        // Block for the first message of a micro-batch — but only when
        // no prompt ingest is pending; with prefill work queued the
        // round must proceed even if the channel stays quiet.
        if prefills.is_empty() {
            match rx.recv() {
                Ok(msg) => handle_msg(
                    msg,
                    &model,
                    &mut res,
                    &mut prefills,
                    &mut steps,
                    &mut closes,
                    &mut exit,
                    &tele,
                    &cache,
                ),
                Err(_) => {
                    // All clients gone.
                    res.sync_gauges();
                    res.sync_ml();
                    return;
                }
            }
        }
        // Fill the micro-batch until the window closes. With prefill
        // work pending, drain whatever is already queued without
        // waiting: decode steps still ride batched rounds, but prompt
        // chunks never idle behind the fill window.
        let deadline = Instant::now() + cfg.max_wait;
        while !exit && steps.len() < cfg.max_steps {
            let msg = if prefills.is_empty() {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(msg) => msg,
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        exit = true;
                        break;
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(msg) => msg,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        exit = true;
                        break;
                    }
                }
            };
            handle_msg(
                msg,
                &model,
                &mut res,
                &mut prefills,
                &mut steps,
                &mut closes,
                &mut exit,
                &tele,
                &cache,
            );
        }

        // Execute the drained work. Both modes partition the micro-batch
        // into rounds of at most one step per session (per-session order
        // is submission order: one scheduler, FIFO channel) and split
        // rounds into waves of at most `cap` distinct streams.
        //
        // Unified planner (default): each wave *also* deals pending
        // prompt chunks round-robin into its spare residency room, and
        // the whole wave — decode steps, speculative verify windows,
        // prompt chunks — runs as ONE stacked `ragged_forward` pass
        // (gather → pass → scatter → commit). Once the decode rounds are
        // exhausted, pure-prefill waves keep draining the prompt queue
        // under the round's budgets.
        //
        // Baseline mode: the PR 3-5 three-phase loop — decode rounds
        // (spec in place, plain `step_many`), then a separate prefill
        // phase. Kept as the bench baseline; logits are bit-identical.
        //
        // Prefill work is skipped once shutdown is requested: queued
        // *steps* are served first (they are already paid for), but
        // mid-ingest prompts fail uniformly below — whatever the budget
        // settings — instead of racing the sentinel.
        let micro_batch = steps.len();
        let t0 = Instant::now();
        let mut tally = RoundTally::default();
        let mut ptally = PrefillTally::default();
        pacer.round_reset();
        // Deadline sweep at the wave boundary: queued ingests whose
        // budget already lapsed fail typed NOW — before any compute is
        // spent on them this round — and their sessions close. (Queued
        // steps are swept inside their waves, same boundary semantics.)
        if !prefills.is_empty() {
            for id in prefills.fail_expired(Instant::now()) {
                ptally.failed += 1;
                ptally.expired += 1;
                res.stream_event(EventKind::DeadlinePrefill, id, "", 0, 0);
                if res.close(id) {
                    ptally.disconnected += 1;
                }
            }
        }
        let mut budget =
            if cfg.prefill_budget == 0 { usize::MAX } else { cfg.prefill_budget };
        if cfg.unified_planner {
            let mut round_iter = partition_rounds(steps).into_iter();
            loop {
                let decode_round = round_iter.next();
                let is_decode_round = decode_round.is_some();
                if !is_decode_round
                    && (exit
                        || prefills.is_empty()
                        || budget == 0
                        || pacer.allowance_tokens() == 0)
                {
                    break;
                }
                let mut wave = decode_round.unwrap_or_default();
                let mut progressed = false;
                loop {
                    let tail = wave.split_off(wave.len().min(res.cap));
                    let room = res.cap.saturating_sub(wave.len());
                    let allowance = budget.min(pacer.allowance_tokens());
                    let mut picks = if exit {
                        Vec::new()
                    } else {
                        prefills.plan_wave(room, allowance)
                    };
                    // A stream with both a queued step and a pending
                    // prompt chunk must not appear twice in one pass;
                    // its chunk waits for a later wave (the rotation
                    // cursor already moved past it, so no starvation).
                    if !wave.is_empty() && !picks.is_empty() {
                        let wave_ids: HashSet<u64> =
                            wave.iter().map(|r| r.session).collect();
                        picks.retain(|p| !wave_ids.contains(&p.session));
                    }
                    if wave.is_empty() && picks.is_empty() {
                        break;
                    }
                    let planned: usize = picks.iter().map(|p| p.len()).sum();
                    budget = budget.saturating_sub(planned);
                    progressed = true;
                    run_planned_wave(
                        wave,
                        picks,
                        &model,
                        &mut res,
                        &mut prefills,
                        cfg.batch_threshold,
                        micro_batch,
                        &mut pacer,
                        &mut tally,
                        &mut ptally,
                        &cache,
                        stride,
                        &tele,
                    );
                    wave = tail;
                    if wave.is_empty() {
                        break;
                    }
                }
                if !is_decode_round && !progressed {
                    break;
                }
            }
        } else {
            for round in partition_rounds(steps) {
                run_round(
                    round,
                    &model,
                    &mut res,
                    cfg.batch_threshold,
                    micro_batch,
                    &mut tally,
                );
            }
            if !exit && !prefills.is_empty() {
                run_prefills(
                    &model,
                    &mut res,
                    &mut prefills,
                    budget,
                    &mut pacer,
                    &mut ptally,
                    &cache,
                    stride,
                );
            }
        }
        let did_work = micro_batch > 0
            || tally.planned_rounds > 0
            || ptally.chunks > 0
            || ptally.failed > 0;
        if did_work {
            // Fold the round's tallies into the registry — one batch of
            // atomic adds per round, the same cadence the old mutex'd
            // struct was updated at.
            let r = tele.registry();
            r.counter("decode.steps").add(tally.ok as u64);
            r.counter("decode.failed_steps").add(tally.failed as u64);
            r.counter("decode.micro_batches").add(u64::from(micro_batch > 0));
            r.counter("decode.batched_steps").add(tally.batched as u64);
            r.counter("decode.step_many_calls").add(tally.step_many_calls as u64);
            r.counter("decode.sessions_closed")
                .add((tally.disconnected + ptally.disconnected) as u64);
            r.counter("decode.draft_proposed").add(tally.draft_proposed as u64);
            r.counter("decode.draft_accepted").add(tally.draft_accepted as u64);
            r.counter("decode.verify_steps").add(tally.verify_steps as u64);
            r.counter("decode.lookahead_hits").add(tally.lookahead_hits as u64);
            if tally.planned_rounds > 0 {
                // Pass rows are ≥ 1, so the gauge's 0-means-unset floor
                // merge reproduces the legacy seeded-min fold exactly.
                r.gauge("decode.rows_per_pass_min").min_nonzero(tally.rows_min as u64);
                r.gauge("decode.rows_per_pass_max").max_with(tally.rows_max as u64);
            }
            r.counter("decode.planned_rounds").add(tally.planned_rounds as u64);
            r.counter("decode.decode_rows").add(tally.decode_rows as u64);
            r.counter("decode.prefill_rows").add(tally.prefill_rows as u64);
            r.counter("decode.verify_rows").add(tally.verify_rows as u64);
            r.counter("decode.prefills").add(ptally.completed as u64);
            r.counter("decode.failed_prefills").add(ptally.failed as u64);
            r.counter("decode.prefill_tokens").add(ptally.tokens as u64);
            r.counter("decode.prefill_chunks").add(ptally.chunks as u64);
            r.float("decode.ttft_secs").add(ptally.ttft_secs);
            r.counter("decode.deadline_expired_steps").add(tally.expired as u64);
            r.counter("decode.deadline_expired_prefills").add(ptally.expired as u64);
            for (tenant, load) in &tally.tenant_steps {
                tenant_counter(r, tenant, "steps").add(load.steps as u64);
                tenant_counter(r, tenant, "failed_steps").add(load.failed_steps as u64);
                tenant_counter(r, tenant, "expired_steps")
                    .add(load.expired_steps as u64);
            }
            r.float("decode.exec_secs").add(t0.elapsed().as_secs_f64());
            res.sync_gauges();
            res.sync_ml();
        }
        // Closes apply only after the window's steps ran: per-sender
        // FIFO means any step a client submitted before dropping its
        // stream is already in `steps`, so a pipelined step_async
        // followed by drop still gets its logits. A close racing a
        // still-pending prefill cancels the ingest too (the opener sees
        // a dropped reply).
        for session in closes {
            prefills.cancel(session);
            let tenant = res.tenant_of(session);
            let trace = res.trace_of(session);
            if res.close(session) {
                tele.registry().counter("decode.sessions_closed").inc();
                if let Some(t) = &tenant {
                    tenant_counter(tele.registry(), t, "closed").inc();
                }
                tele.event(
                    EventKind::StreamClose,
                    session,
                    tenant.as_deref().unwrap_or(""),
                    trace,
                    "",
                    0,
                    0,
                );
            }
        }
        queue_depth.store(prefills.len(), Ordering::Relaxed);
        if exit {
            let orphaned = prefills.len();
            prefills.fail_all("decode server shut down during prefill");
            queue_depth.store(0, Ordering::Relaxed);
            tele.registry().counter("decode.failed_prefills").add(orphaned as u64);
            res.sync_gauges();
            res.sync_ml();
            return;
        }
    }
}

/// Per-round prefill execution counters (folded into [`DecodeStats`]).
#[derive(Default)]
struct PrefillTally {
    completed: usize,
    failed: usize,
    tokens: usize,
    chunks: usize,
    ttft_secs: f64,
    /// Streams force-closed because their ingest failed.
    disconnected: usize,
    /// Ingests cancelled by deadline expiry (subset of `failed`).
    expired: usize,
}

/// Wall-time prefill budgeter: an EWMA cost model over measured
/// seconds-per-prompt-token converts `prefill_budget_ms` into a token
/// allowance each round. The model persists across rounds (costs drift
/// slowly — model size and thread count are fixed, cache state is not);
/// the per-round spend resets every scheduler wake-up. Until the first
/// measurement lands there is no basis to throttle, so the allowance is
/// unlimited; afterwards at least one token is always allowed at the
/// start of a round, so ingest makes progress even when a single token
/// overruns the budget.
struct PrefillPacer {
    budget_ms: f64,
    /// EWMA seconds per prompt token (0 until the first sample).
    secs_per_token: f64,
    /// Prefill seconds spent in the current round.
    spent_secs: f64,
}

impl PrefillPacer {
    /// EWMA weight of each new sample.
    const ALPHA: f64 = 0.25;

    fn new(budget_ms: f64) -> PrefillPacer {
        PrefillPacer { budget_ms, secs_per_token: 0.0, spent_secs: 0.0 }
    }

    fn round_reset(&mut self) {
        self.spent_secs = 0.0;
    }

    /// Prompt tokens the current round may still ingest.
    fn allowance_tokens(&self) -> usize {
        if self.budget_ms <= 0.0 {
            return usize::MAX;
        }
        let remaining = self.budget_ms / 1e3 - self.spent_secs;
        if remaining <= 0.0 {
            return 0;
        }
        if self.secs_per_token <= 0.0 {
            return usize::MAX;
        }
        let allow = (remaining / self.secs_per_token).floor() as usize;
        if allow == 0 && self.spent_secs == 0.0 {
            1
        } else {
            allow
        }
    }

    /// Fold one measured chunk (`tokens` prompt tokens in `secs`) into
    /// the cost model and the round's spend.
    fn record(&mut self, tokens: usize, secs: f64) {
        if tokens == 0 {
            return;
        }
        self.spent_secs += secs;
        let sample = secs / tokens as f64;
        self.secs_per_token = if self.secs_per_token <= 0.0 {
            sample
        } else {
            (1.0 - Self::ALPHA) * self.secs_per_token + Self::ALPHA * sample
        };
    }
}

/// Baseline-mode prefill phase: ingest pending prompt chunks —
/// round-robin across queued streams ([`PrefillQueue::plan_wave`]) —
/// until the round's token budget or wall-time allowance is spent. Each
/// chunk is one stacked [`DecoderSession::prefill_chunk`] pass;
/// residency interacts only at these chunk boundaries — a spilled
/// prefilling stream restores on its next chunk (pinning only itself,
/// so restores can evict idle streams), and between chunks it is an
/// ordinary LRU citizen. A chunk failure (lost snapshot, untrusted
/// state) fails that prompt's open and disconnects only that stream.
#[allow(clippy::too_many_arguments)]
fn run_prefills(
    model: &Arc<HostDecoder>,
    res: &mut Residency,
    queue: &mut PrefillQueue,
    budget: usize,
    pacer: &mut PrefillPacer,
    tally: &mut PrefillTally,
    cache: &Mutex<PrefixCache>,
    stride: usize,
) {
    let mut budget = budget;
    loop {
        let allowance = budget.min(pacer.allowance_tokens());
        let Some(plan) = queue.plan_wave(1, allowance).pop() else { break };
        let id = plan.session;
        let ready = match res.ensure_resident(id, model, &[id]) {
            Ok(true) => Ok(()),
            Ok(false) => Err(anyhow!("unknown or closed session {id}")),
            Err(e) => Err(anyhow!("restoring spilled session {id}: {e:#}")),
        };
        let t0 = Instant::now();
        let result = ready.and_then(|()| {
            let tokens = queue.tokens(&plan);
            match res.resident.get_mut(&id) {
                Some(Slot::Plain(sess)) => sess.prefill_chunk(tokens, plan.is_last),
                Some(Slot::Spec(spec)) => spec.prefill_chunk(tokens, plan.is_last),
                None => Err(anyhow!("unknown or closed session {id}")),
            }
        });
        match result {
            Ok(logits) => {
                let took = plan.len();
                pacer.record(took, t0.elapsed().as_secs_f64());
                budget = budget.saturating_sub(took);
                tally.tokens += took;
                tally.chunks += 1;
                res.touch(id);
                if plan.is_last {
                    let logits = logits.expect("final chunk emits logits");
                    tally.ttft_secs += queue.finish(id, logits);
                    tally.completed += 1;
                } else {
                    maybe_cache_prefix(cache, stride, res, queue, id, plan.end());
                    queue.advance(id, took);
                }
            }
            Err(e) => {
                queue.fail(id, e);
                tally.failed += 1;
                if res.close(id) {
                    tally.disconnected += 1;
                }
            }
        }
    }
}

/// Offer a just-ingested prompt boundary to the prefix cache. Called
/// after a non-final chunk of `id` ran (so the session's state embodies
/// exactly `end` prompt tokens) and before the queue cursor advances.
/// Inserts only at `stride`-aligned boundaries, skips prefixes some
/// concurrent same-prefix open already covered (the dedupe the tree
/// gives us for free), and never fails the stream: a snapshot error
/// just means this boundary is not cached.
fn maybe_cache_prefix(
    cache: &Mutex<PrefixCache>,
    stride: usize,
    res: &mut Residency,
    queue: &PrefillQueue,
    id: u64,
    end: usize,
) {
    if stride == 0 || end == 0 || end % stride != 0 {
        return;
    }
    let Some(prefix) = queue.ingested_prefix(id, end) else { return };
    let ns: Arc<str> = res.tenant_of(id).unwrap_or_else(|| Arc::from(""));
    // Snapshot only when this exact prefix is new — `covered` is the
    // cross-stream dedupe for concurrent same-prompt opens.
    {
        let c = lock_cache(cache);
        if !c.enabled() || c.covered(&ns, prefix) {
            return;
        }
    }
    let prefix = prefix.to_vec();
    let Some(Ok(snap)) = res.resident.get_mut(&id).map(|s| s.snapshot()) else {
        return;
    };
    lock_cache(cache).insert(&ns, &prefix, snap);
}

/// Per-micro-batch execution counters (folded into [`DecodeStats`]).
#[derive(Default)]
struct RoundTally {
    ok: usize,
    failed: usize,
    batched: usize,
    step_many_calls: usize,
    /// Sessions force-closed because a batched round failed mid-flight
    /// (their per-layer states can no longer be trusted).
    disconnected: usize,
    /// Speculation counters drained from the streams' own sessions.
    draft_proposed: usize,
    draft_accepted: usize,
    verify_steps: usize,
    lookahead_hits: usize,
    /// Unified-planner counters: stacked passes driven and their row
    /// composition. `rows_min` is only meaningful when
    /// `planned_rounds > 0` (it is seeded by the first pass).
    planned_rounds: usize,
    decode_rows: usize,
    prefill_rows: usize,
    verify_rows: usize,
    rows_min: usize,
    rows_max: usize,
    /// Steps cancelled at the wave boundary by deadline expiry (subset
    /// of `failed`).
    expired: usize,
    /// Per-tenant step outcomes for tagged streams (only the step
    /// fields of [`TenantLoad`] are populated here).
    tenant_steps: HashMap<Arc<str>, TenantLoad>,
}

impl RoundTally {
    /// Per-tenant accumulator row for a tagged step request.
    fn tenant_entry(&mut self, tenant: &Option<Arc<str>>) -> Option<&mut TenantLoad> {
        tenant.as_ref().map(|t| self.tenant_steps.entry(t.clone()).or_default())
    }
}

/// Split a drained micro-batch into rounds with at most one step per
/// session each, preserving per-session submission order: a session's
/// second queued step lands in the round after its first.
fn partition_rounds(steps: Vec<StepReq>) -> Vec<Vec<StepReq>> {
    let mut rounds: Vec<Vec<StepReq>> = Vec::new();
    let mut next_round: HashMap<u64, usize> = HashMap::new();
    for req in steps {
        let r = next_round.entry(req.session).or_insert(0);
        if rounds.len() == *r {
            rounds.push(Vec::new());
        }
        rounds[*r].push(req);
        *r += 1;
    }
    rounds
}

/// Deliver one step's outcome to its waiting client and fold it into
/// the tally — the single reply path shared by the scalar, degenerate
/// batched and speculative steps (so `StepOut` construction and the
/// ok/failed accounting can never drift between them).
fn reply_step(
    req: StepReq,
    result: Result<Vec<f32>>,
    pos: usize,
    micro_batch: usize,
    tally: &mut RoundTally,
) {
    match result {
        Ok(logits) => {
            tally.ok += 1;
            if let Some(t) = tally.tenant_entry(&req.tenant) {
                t.steps += 1;
            }
            req.reply
                .send(Ok(StepOut {
                    session: req.session,
                    pos,
                    logits,
                    latency: req.submitted.elapsed(),
                    micro_batch,
                }))
                .ok();
        }
        Err(e) => {
            tally.failed += 1;
            if let Some(t) = tally.tenant_entry(&req.tenant) {
                t.failed_steps += 1;
            }
            req.reply.send(Err(e)).ok();
        }
    }
}

/// Scalar fallback: one session, one step, one reply.
fn scalar_step(
    req: StepReq,
    sess: &mut DecoderSession,
    micro_batch: usize,
    tally: &mut RoundTally,
) {
    let pos = sess.position();
    let result = sess.step(req.token);
    reply_step(req, result, pos, micro_batch, tally);
}

/// One speculative stream step: served from verified lookahead when the
/// submitted token matches the predicted greedy continuation, otherwise
/// a fresh draft-propose / verify-accept window
/// ([`SpeculativeSession::step`]). Each such step is already a stacked
/// multi-token verify on its own stream, so it does not join the
/// cross-session batch; its counters drain into the tally either way.
fn spec_step(
    req: StepReq,
    spec: &mut SpeculativeSession,
    micro_batch: usize,
    tally: &mut RoundTally,
) {
    let pos = spec.position();
    let result = spec.step(req.token);
    reply_step(req, result, pos, micro_batch, tally);
    drain_spec_counters(spec, tally);
}

/// Fold a speculative stream's per-step counters into the round tally —
/// shared by the in-place [`spec_step`] path and the planner's
/// plan/finish split, so the accounting can never drift between them.
fn drain_spec_counters(spec: &mut SpeculativeSession, tally: &mut RoundTally) {
    let c = spec.take_counters();
    tally.draft_proposed += c.draft_proposed;
    tally.draft_accepted += c.draft_accepted;
    tally.verify_steps += c.verify_steps;
    tally.lookahead_hits += c.lookahead_hits;
}

/// Execute one round, splitting it into waves of at most
/// `max_resident_sessions` distinct streams: every wave's sessions are
/// made resident (restoring spills) before it runs, and because a wave
/// never pins more streams than the cap, restores can always make room
/// by evicting idle streams — residency never overshoots the cap.
fn run_round(
    round: Vec<StepReq>,
    model: &Arc<HostDecoder>,
    res: &mut Residency,
    batch_threshold: usize,
    micro_batch: usize,
    tally: &mut RoundTally,
) {
    let cap = res.cap;
    let mut wave = round;
    while !wave.is_empty() {
        let tail = wave.split_off(wave.len().min(cap));
        run_wave(wave, model, res, batch_threshold, micro_batch, tally);
        wave = tail;
    }
}

/// Cancel (typed error) every step in `wave` whose deadline has already
/// passed; returns the still-live remainder. Runs at the wave boundary
/// — before any restore or compute is spent on the expired steps — and
/// the session does NOT advance, so the caller may resubmit the same
/// token and the stream stays bit-exact. Shared by both wave flavors so
/// deadline semantics cannot drift between planner and baseline.
fn sweep_expired(
    wave: Vec<StepReq>,
    res: &Residency,
    tally: &mut RoundTally,
) -> Vec<StepReq> {
    let now = Instant::now();
    if !wave.iter().any(|r| r.deadline.map_or(false, |d| d <= now)) {
        return wave;
    }
    let mut live = Vec::with_capacity(wave.len());
    for req in wave {
        if req.deadline.map_or(false, |d| d <= now) {
            tally.failed += 1;
            tally.expired += 1;
            if let Some(t) = tally.tenant_entry(&req.tenant) {
                t.failed_steps += 1;
                t.expired_steps += 1;
            }
            res.stream_event(EventKind::DeadlineStep, req.session, "", 0, 0);
            req.reply
                .send(Err(anyhow!(
                    "deadline expired before execution (session {})",
                    req.session
                )))
                .ok();
        } else {
            live.push(req);
        }
    }
    live
}

/// Residency status of one wave member after the restore phase.
enum WaveStatus {
    /// In the session table, ready to step.
    Ready,
    /// Never opened, or closed — the canonical "unknown" error.
    Unknown,
    /// A spill snapshot existed but could not be restored; the state is
    /// lost and only this stream disconnects.
    Lost(String),
}

/// Execute one wave (≤ cap distinct sessions, ≤ 1 step each): restore
/// phase first, then the batched [`step_many`] path — or scalar `step`
/// for sub-threshold waves and out-of-vocab tokens (the scalar error is
/// the canonical one, and the session must not advance).
fn run_wave(
    wave: Vec<StepReq>,
    model: &Arc<HostDecoder>,
    res: &mut Residency,
    batch_threshold: usize,
    micro_batch: usize,
    tally: &mut RoundTally,
) {
    // Phase 0: deadline sweep at the wave boundary.
    let wave = sweep_expired(wave, res, tally);
    // Phase 1: bring every spilled session in this wave back into the
    // table. The whole wave is pinned so one member's restore cannot
    // evict another's just-restored state.
    let ids: Vec<u64> = wave.iter().map(|r| r.session).collect();
    let mut status: HashMap<u64, WaveStatus> = HashMap::with_capacity(ids.len());
    for &id in &ids {
        let st = match res.ensure_resident(id, model, &ids) {
            Ok(true) => WaveStatus::Ready,
            Ok(false) => WaveStatus::Unknown,
            Err(e) => WaveStatus::Lost(format!("{e:#}")),
        };
        status.insert(id, st);
    }
    let mut runnable: Vec<StepReq> = Vec::with_capacity(wave.len());
    for req in wave {
        let id = req.session;
        match status.get(&id) {
            Some(WaveStatus::Ready) => runnable.push(req),
            Some(WaveStatus::Lost(msg)) => {
                tally.failed += 1;
                tally.disconnected += 1;
                if let Some(t) = tally.tenant_entry(&req.tenant) {
                    t.failed_steps += 1;
                }
                req.reply
                    .send(Err(anyhow!("restoring spilled session {id}: {msg}")))
                    .ok();
                // The state is lost: fully close the stream so its
                // bookkeeping (and any stale spill blob — a disk file
                // under DiskStore) is released now, not at server drop.
                res.close(id);
            }
            Some(WaveStatus::Unknown) | None => {
                tally.failed += 1;
                req.reply.send(Err(anyhow!("unknown or closed session {id}"))).ok();
            }
        }
    }

    // Phase 2a: speculative streams step in place — each speculative
    // step is already a stacked multi-token verify on its own stream,
    // so only plain streams join the cross-session batch.
    let mut plain: Vec<StepReq> = Vec::with_capacity(runnable.len());
    for req in runnable {
        let id = req.session;
        match res.resident.get_mut(&id) {
            Some(Slot::Spec(spec)) => {
                spec_step(req, spec, micro_batch, tally);
                res.touch(id);
            }
            Some(Slot::Plain(_)) => plain.push(req),
            None => {
                tally.failed += 1;
                req.reply.send(Err(anyhow!("unknown or closed session {id}"))).ok();
            }
        }
    }

    // Phase 2b: plain streams — batched step_many, or the PR 1 scalar
    // loop for sub-threshold waves.
    let batch = plain.len() >= batch_threshold.max(2);
    if !batch {
        for req in plain {
            let id = req.session;
            match res.resident.get_mut(&id) {
                Some(Slot::Plain(sess)) => {
                    scalar_step(req, sess, micro_batch, tally);
                    res.touch(id);
                }
                _ => {
                    tally.failed += 1;
                    req.reply.send(Err(anyhow!("unknown or closed session {id}"))).ok();
                }
            }
        }
        return;
    }
    let vocab = model.config().vocab;
    let mut work: Vec<(StepReq, DecoderSession)> = Vec::with_capacity(plain.len());
    for req in plain {
        // Each id was seen as Plain moments ago on this same thread, so
        // the removal can only yield a plain slot (or nothing).
        let Some(Slot::Plain(mut sess)) = res.resident.remove(&req.session) else {
            tally.failed += 1;
            req.reply
                .send(Err(anyhow!("unknown or closed session {}", req.session)))
                .ok();
            continue;
        };
        let in_vocab = req.token >= 0 && (req.token as usize) < vocab;
        if !in_vocab {
            // Scalar path yields the canonical out-of-vocab error and
            // leaves the session unadvanced.
            let id = req.session;
            scalar_step(req, &mut sess, micro_batch, tally);
            res.resident.insert(id, Slot::Plain(sess));
            res.touch(id);
            continue;
        }
        work.push((req, sess));
    }
    if work.len() < 2 {
        // Batched wave degenerated (filtered down): finish scalar.
        for (req, mut sess) in work {
            let id = req.session;
            scalar_step(req, &mut sess, micro_batch, tally);
            res.resident.insert(id, Slot::Plain(sess));
            res.touch(id);
        }
        return;
    }
    let n = work.len();
    let tokens: Vec<i32> = work.iter().map(|(r, _)| r.token).collect();
    let poses: Vec<usize> = work.iter().map(|(_, s)| s.position()).collect();
    let result = {
        let mut refs: Vec<&mut DecoderSession> =
            work.iter_mut().map(|(_, s)| s).collect();
        step_many(&mut refs, &tokens)
    };
    match result {
        Ok(rows) => {
            tally.step_many_calls += 1;
            tally.batched += n;
            for (((req, sess), logits), pos) in
                work.into_iter().zip(rows).zip(poses)
            {
                tally.ok += 1;
                if let Some(t) = tally.tenant_entry(&req.tenant) {
                    t.steps += 1;
                }
                req.reply
                    .send(Ok(StepOut {
                        session: req.session,
                        pos,
                        logits,
                        latency: req.submitted.elapsed(),
                        micro_batch,
                    }))
                    .ok();
                res.resident.insert(req.session, Slot::Plain(sess));
                res.touch(req.session);
            }
        }
        Err(e) => {
            // Unreachable after the vocab pre-check — but if a batched
            // round ever fails mid-layer, per-head states may be
            // partially advanced, so the sessions cannot be trusted:
            // disconnect them (PR 1 policy: failed batches disconnect
            // clients and count in stats). Later steps on these streams
            // get a clean "unknown or closed session" error.
            for (req, sess) in work {
                tally.failed += 1;
                tally.disconnected += 1;
                if let Some(t) = tally.tenant_entry(&req.tenant) {
                    t.failed_steps += 1;
                }
                req.reply.send(Err(anyhow!("batched step failed: {e}"))).ok();
                res.close(req.session);
                drop(sess);
            }
        }
    }
}

/// What one planned-wave participant contributes to the stacked pass,
/// and what its scatter step owes afterwards.
enum PlannedPart {
    /// Plain decode step riding the pass (request + pre-step position).
    Plain(StepReq, usize),
    /// Speculative verify window (request + pre-step position); the
    /// window itself lives in the parallel `windows` vector.
    Verify(StepReq, usize),
    /// One prompt chunk of a queued prefill.
    Chunk(ChunkPlan),
}

/// Execute one *planned* wave — the unified ragged-batch planner's
/// inner step. `wave` holds ≤ cap distinct sessions' decode steps (≤ 1
/// each); `picks` holds prompt chunks dealt into the wave's spare
/// residency room. The wave runs as:
///
/// 1. **Restore** — every participant (steps and chunks) is made
///    resident, the whole wave pinned so one member's restore cannot
///    evict another's just-restored state.
/// 2. **Plan** — each participant yields its window: a plain step is a
///    1-token segment (out-of-vocab or sub-`batch_threshold` plains
///    fall back to the canonical scalar path); a speculative step
///    either answers from lookahead immediately or yields its K+1-token
///    verify window ([`SpeculativeSession::plan_step`]); a prompt chunk
///    yields its ≤ C tokens (speculative streams first rewind to their
///    committed boundary).
/// 3. **Execute** — all windows run as ONE stacked [`ragged_forward`]
///    pass over the concatenated panel.
/// 4. **Scatter/commit** — logits rows fan back out: plain steps reply,
///    verify windows run accept/rollback
///    ([`SpeculativeSession::finish_step`]), chunks advance or finish
///    their queue entry. The prefill share of the pass's wall time
///    feeds the [`PrefillPacer`] cost model.
///
/// Bit-identity: every window advances through the same per-stream
/// recurrence and prepacked GEMMs as its scalar per-kind path (see
/// [`ragged_forward`]), so fusing the traffic kinds never perturbs any
/// stream's logits — including under residency caps, because restore
/// happens before the pass and spills only between waves.
#[allow(clippy::too_many_arguments)]
fn run_planned_wave(
    wave: Vec<StepReq>,
    picks: Vec<ChunkPlan>,
    model: &Arc<HostDecoder>,
    res: &mut Residency,
    queue: &mut PrefillQueue,
    batch_threshold: usize,
    micro_batch: usize,
    pacer: &mut PrefillPacer,
    tally: &mut RoundTally,
    ptally: &mut PrefillTally,
    cache: &Mutex<PrefixCache>,
    stride: usize,
    tele: &Telemetry,
) {
    // Span sampling decision for this wave (every `telemetry_sample`-th
    // wave; 0 = never). Observation-only: the unsampled path takes no
    // extra timestamps and the math is identical either way.
    let sampled = tele.sample_wave();
    let spans = SpanCells::default();
    let t_restore = if sampled { Some(Instant::now()) } else { None };
    // Phase 0: deadline sweep at the wave boundary. (Queued prompt
    // ingests are swept once per round in the scheduler loop.)
    let wave = sweep_expired(wave, res, tally);
    // Phase 1: restore. Pin steps and chunks alike.
    let mut ids: Vec<u64> = wave.iter().map(|r| r.session).collect();
    ids.extend(picks.iter().map(|p| p.session));
    let mut status: HashMap<u64, WaveStatus> = HashMap::with_capacity(ids.len());
    for &id in &ids {
        let st = match res.ensure_resident(id, model, &ids) {
            Ok(true) => WaveStatus::Ready,
            Ok(false) => WaveStatus::Unknown,
            Err(e) => WaveStatus::Lost(format!("{e:#}")),
        };
        status.insert(id, st);
    }
    let mut runnable: Vec<StepReq> = Vec::with_capacity(wave.len());
    for req in wave {
        let id = req.session;
        match status.get(&id) {
            Some(WaveStatus::Ready) => runnable.push(req),
            Some(WaveStatus::Lost(msg)) => {
                tally.failed += 1;
                tally.disconnected += 1;
                if let Some(t) = tally.tenant_entry(&req.tenant) {
                    t.failed_steps += 1;
                }
                req.reply
                    .send(Err(anyhow!("restoring spilled session {id}: {msg}")))
                    .ok();
                // The state is lost: fully close the stream so its
                // bookkeeping (and any stale spill blob — a disk file
                // under DiskStore) is released now, not at server drop.
                res.close(id);
            }
            Some(WaveStatus::Unknown) | None => {
                tally.failed += 1;
                req.reply.send(Err(anyhow!("unknown or closed session {id}"))).ok();
            }
        }
    }
    let mut chunks: Vec<ChunkPlan> = Vec::with_capacity(picks.len());
    for pick in picks {
        let id = pick.session;
        match status.get(&id) {
            Some(WaveStatus::Ready) => chunks.push(pick),
            Some(WaveStatus::Lost(msg)) => {
                queue.fail(id, anyhow!("restoring spilled session {id}: {msg}"));
                ptally.failed += 1;
                if res.close(id) {
                    ptally.disconnected += 1;
                }
            }
            Some(WaveStatus::Unknown) | None => {
                queue.fail(id, anyhow!("unknown or closed session {id}"));
                ptally.failed += 1;
                if res.close(id) {
                    ptally.disconnected += 1;
                }
            }
        }
    }

    let restore_s = t_restore.map(|t| t.elapsed().as_secs_f64());
    let t_plan = if sampled { Some(Instant::now()) } else { None };
    // Phase 2: plan. Sub-threshold plain rounds keep the scalar path —
    // `batch_threshold` semantics (including `usize::MAX` = never
    // batch) are unchanged under the planner.
    let vocab = model.config().vocab;
    let plain_candidates = runnable
        .iter()
        .filter(|r| {
            matches!(res.resident.get(&r.session), Some(Slot::Plain(_)))
                && r.token >= 0
                && (r.token as usize) < vocab
        })
        .count();
    let batch_plains = plain_candidates >= batch_threshold.max(2);

    // Participants, as parallel vectors: the segments borrow `windows`
    // while the session refs borrow `slots`, so the two must be
    // separately owned.
    let mut part_ids: Vec<u64> = Vec::new();
    let mut slots: Vec<Slot> = Vec::new();
    let mut kinds: Vec<PlannedPart> = Vec::new();
    let mut windows: Vec<Vec<i32>> = Vec::new();
    let mut emits: Vec<Emit> = Vec::new();

    for req in runnable {
        let id = req.session;
        let Some(slot) = res.resident.remove(&id) else {
            tally.failed += 1;
            req.reply.send(Err(anyhow!("unknown or closed session {id}"))).ok();
            continue;
        };
        match slot {
            Slot::Plain(mut sess) => {
                let in_vocab = req.token >= 0 && (req.token as usize) < vocab;
                if !batch_plains || !in_vocab {
                    // Scalar path: canonical out-of-vocab error, and
                    // the session must not advance on a bad token.
                    scalar_step(req, &mut sess, micro_batch, tally);
                    res.resident.insert(id, Slot::Plain(sess));
                    res.touch(id);
                    continue;
                }
                let pos = sess.position();
                part_ids.push(id);
                slots.push(Slot::Plain(sess));
                windows.push(vec![req.token]);
                emits.push(Emit::Last);
                kinds.push(PlannedPart::Plain(req, pos));
            }
            Slot::Spec(mut spec) => {
                let pos = spec.position();
                match spec.plan_step(req.token) {
                    Ok(SpecPlan::Ready(logits)) => {
                        // Lookahead hit (or trivial window): answered
                        // without joining the pass.
                        reply_step(req, Ok(logits), pos, micro_batch, tally);
                        drain_spec_counters(&mut spec, tally);
                        res.resident.insert(id, Slot::Spec(spec));
                        res.touch(id);
                    }
                    Ok(SpecPlan::Verify(window)) => {
                        part_ids.push(id);
                        slots.push(Slot::Spec(spec));
                        windows.push(window);
                        emits.push(Emit::All);
                        kinds.push(PlannedPart::Verify(req, pos));
                    }
                    Err(e) => {
                        reply_step(req, Err(e), pos, micro_batch, tally);
                        drain_spec_counters(&mut spec, tally);
                        res.resident.insert(id, Slot::Spec(spec));
                        res.touch(id);
                    }
                }
            }
        }
    }
    for pick in chunks {
        let id = pick.session;
        let Some(mut slot) = res.resident.remove(&id) else {
            queue.fail(id, anyhow!("unknown or closed session {id}"));
            ptally.failed += 1;
            continue;
        };
        if let Slot::Spec(spec) = &mut slot {
            // Rewind to the committed boundary before prompt tokens
            // land; a failed rewind leaves the state untrusted, so only
            // this stream disconnects.
            if let Err(e) = spec.plan_prefill() {
                queue.fail(id, e);
                ptally.failed += 1;
                res.close(id);
                ptally.disconnected += 1;
                continue;
            }
        }
        part_ids.push(id);
        slots.push(slot);
        windows.push(queue.tokens(&pick).to_vec());
        emits.push(if pick.is_last { Emit::Last } else { Emit::None });
        kinds.push(PlannedPart::Chunk(pick));
    }

    if part_ids.is_empty() {
        return;
    }

    // Phase 3: execute — one stacked pass over every window.
    let mut decode_rows = 0usize;
    let mut verify_rows = 0usize;
    let mut prefill_rows = 0usize;
    for (kind, window) in kinds.iter().zip(&windows) {
        match kind {
            PlannedPart::Plain(..) => decode_rows += window.len(),
            PlannedPart::Verify(..) => verify_rows += window.len(),
            PlannedPart::Chunk(_) => prefill_rows += window.len(),
        }
    }
    let total_rows = decode_rows + verify_rows + prefill_rows;
    tally.planned_rounds += 1;
    tally.decode_rows += decode_rows;
    tally.verify_rows += verify_rows;
    tally.prefill_rows += prefill_rows;
    tally.rows_min = if tally.planned_rounds == 1 {
        total_rows
    } else {
        tally.rows_min.min(total_rows)
    };
    tally.rows_max = tally.rows_max.max(total_rows);
    if decode_rows >= 2 {
        tally.step_many_calls += 1;
        tally.batched += decode_rows;
    }
    let plan_s = t_plan.map(|t| t.elapsed().as_secs_f64());
    let t0 = Instant::now();
    let result = {
        let segs: Vec<SegmentSpec> = windows
            .iter()
            .zip(&emits)
            .map(|(w, &emit)| SegmentSpec { tokens: w, emit })
            .collect();
        let mut refs: Vec<&mut DecoderSession> = slots
            .iter_mut()
            .map(|slot| match slot {
                Slot::Plain(sess) => sess,
                Slot::Spec(spec) => spec.session_mut(),
            })
            .collect();
        ragged_forward_spanned(&mut refs, &segs, if sampled { Some(&spans) } else { None })
    };
    let pass_secs = t0.elapsed().as_secs_f64();
    let t_scatter = if sampled { Some(Instant::now()) } else { None };

    // Phase 4: scatter and commit.
    match result {
        Ok(rows) => {
            if prefill_rows > 0 {
                // Attribute the pass's wall time to the prefill rows by
                // their share of the panel — the EWMA the wall-time
                // budget paces on.
                pacer.record(
                    prefill_rows,
                    pass_secs * prefill_rows as f64 / total_rows as f64,
                );
            }
            for ((((id, slot), kind), window), seg_rows) in
                part_ids.into_iter().zip(slots).zip(kinds).zip(windows).zip(rows)
            {
                match kind {
                    PlannedPart::Plain(req, pos) => {
                        let logits =
                            seg_rows.into_iter().next().expect("one row per decode step");
                        reply_step(req, Ok(logits), pos, micro_batch, tally);
                        res.resident.insert(id, slot);
                        res.touch(id);
                    }
                    PlannedPart::Verify(req, pos) => {
                        let mut slot = slot;
                        let outcome = match &mut slot {
                            Slot::Spec(spec) => spec.finish_step(&window, seg_rows),
                            Slot::Plain(_) => {
                                Err(anyhow!("verify window planned on a plain stream"))
                            }
                        };
                        reply_step(req, outcome, pos, micro_batch, tally);
                        if let Slot::Spec(spec) = &mut slot {
                            drain_spec_counters(spec, tally);
                        }
                        res.resident.insert(id, slot);
                        res.touch(id);
                    }
                    PlannedPart::Chunk(pick) => {
                        ptally.tokens += window.len();
                        ptally.chunks += 1;
                        let mut slot = slot;
                        if let Slot::Spec(spec) = &mut slot {
                            spec.finish_prefill(&window);
                        }
                        res.resident.insert(id, slot);
                        res.touch(id);
                        if pick.is_last {
                            let logits = seg_rows
                                .into_iter()
                                .next()
                                .expect("final chunk emits logits");
                            ptally.ttft_secs += queue.finish(id, logits);
                            ptally.completed += 1;
                        } else {
                            maybe_cache_prefix(
                                cache,
                                stride,
                                res,
                                queue,
                                id,
                                pick.end(),
                            );
                            queue.advance(id, window.len());
                        }
                    }
                }
            }
        }
        Err(e) => {
            // Unreachable after the vocab pre-checks — but if a stacked
            // pass ever fails mid-layer, per-head states may be
            // partially advanced, so none of the participants can be
            // trusted: disconnect them all (the PR 1 failed-batch
            // policy). Later steps on these streams get a clean
            // "unknown or closed session" error.
            for ((id, slot), kind) in part_ids.into_iter().zip(slots).zip(kinds) {
                match kind {
                    PlannedPart::Plain(req, _) | PlannedPart::Verify(req, _) => {
                        tally.failed += 1;
                        tally.disconnected += 1;
                        if let Some(t) = tally.tenant_entry(&req.tenant) {
                            t.failed_steps += 1;
                        }
                        req.reply.send(Err(anyhow!("batched step failed: {e}"))).ok();
                        res.close(id);
                    }
                    PlannedPart::Chunk(_) => {
                        queue.fail(id, anyhow!("batched step failed: {e}"));
                        ptally.failed += 1;
                        res.close(id);
                        ptally.disconnected += 1;
                    }
                }
                drop(slot);
            }
        }
    }

    // Sampled-wave telemetry: the per-phase span histograms, the
    // rows-vs-latency ledger entry, and one `wave` flight-recorder
    // event (`a` = total rows, `b` = pass µs).
    if sampled {
        let r = tele.registry();
        let lat = |name: &str, v: f64| {
            r.histogram(name, &LATENCY_BOUNDS_S).observe(v);
        };
        lat("decode.wave.restore_s", restore_s.unwrap_or(0.0));
        lat("decode.wave.plan_s", plan_s.unwrap_or(0.0));
        lat("decode.wave.gather_s", spans.gather_s.get());
        lat("decode.wave.gemm_s", spans.gemm_s.get());
        lat("decode.wave.advance_s", spans.advance_s.get());
        lat("decode.wave.readout_s", spans.readout_s.get());
        lat(
            "decode.wave.scatter_s",
            t_scatter.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0),
        );
        r.ledger("decode.rows_vs_latency", &ROWS_BOUNDS)
            .record(total_rows as u64, pass_secs);
        tele.event(
            EventKind::Wave,
            0,
            "",
            0,
            "",
            total_rows as u64,
            (pass_secs * 1e6) as u64,
        );
    }
}

#[allow(clippy::too_many_arguments)]
fn handle_msg(
    msg: DecodeMsg,
    model: &Arc<HostDecoder>,
    res: &mut Residency,
    prefills: &mut PrefillQueue,
    steps: &mut Vec<StepReq>,
    closes: &mut Vec<u64>,
    exit: &mut bool,
    tele: &Telemetry,
    cache: &Mutex<PrefixCache>,
) {
    match msg {
        DecodeMsg::Open { session, speculative, tenant, trace, reply } => {
            let opened = res.open(session, model, speculative);
            if opened.is_ok() {
                tele.registry().counter("decode.sessions_opened").inc();
                if let Some(t) = &tenant {
                    tenant_counter(tele.registry(), t, "opened").inc();
                    res.tenants.insert(session, t.clone());
                }
                res.traces.insert(session, trace);
                tele.event(
                    EventKind::StreamOpen,
                    session,
                    tenant.as_deref().unwrap_or(""),
                    trace,
                    "",
                    0,
                    0,
                );
            }
            reply.send(opened).ok();
        }
        DecodeMsg::OpenWithPrompt {
            session,
            speculative,
            tenant,
            deadline,
            trace,
            prompt,
            submitted,
            reply,
        } => {
            // Validate the whole prompt before the session exists: a
            // bad prompt fails the open without registering anything.
            let admitted = prefill::validate_prompt(&prompt, model.config().vocab)
                .and_then(|()| res.open(session, model, speculative));
            match admitted {
                Ok(()) => {
                    tele.registry().counter("decode.sessions_opened").inc();
                    let tenant_slug = tenant.as_deref().unwrap_or("").to_string();
                    if let Some(t) = &tenant {
                        tenant_counter(tele.registry(), t, "opened").inc();
                        res.tenants.insert(session, t.clone());
                    }
                    res.traces.insert(session, trace);
                    tele.event(
                        EventKind::StreamOpen,
                        session,
                        &tenant_slug,
                        trace,
                        "",
                        prompt.len() as u64,
                        0,
                    );
                    // Prefix-cache walk (tenant-scoped namespace):
                    // restore the deepest cached ancestor and enqueue
                    // only the uncovered suffix. The hit pins its node
                    // until released here, so eviction pressure from
                    // concurrent inserts cannot free the snapshot
                    // mid-restore. Each outcome lands in the flight
                    // recorder: hit/partial (`a` = restored depth),
                    // miss, or poison (adopt failure → cold prefill).
                    let mut restored = 0;
                    let cache_on = lock_cache(cache).enabled();
                    let hit = lock_cache(cache).lookup(&tenant_slug, &prompt);
                    match hit {
                        Some(hit) => {
                            match res.adopt_snapshot(session, model, &hit.snapshot) {
                                Ok(()) => {
                                    restored = hit.depth;
                                    let mut c = lock_cache(cache);
                                    c.note_restored(hit.depth);
                                    c.release(hit.node);
                                    drop(c);
                                    let kind = if hit.full {
                                        EventKind::PrefixHit
                                    } else {
                                        EventKind::PrefixPartial
                                    };
                                    tele.event(
                                        kind,
                                        session,
                                        &tenant_slug,
                                        trace,
                                        "",
                                        hit.depth as u64,
                                        0,
                                    );
                                }
                                // Failure envelope: a truncated or
                                // fingerprint-mismatched cached snapshot is
                                // a cache *miss*, never a client error —
                                // the open falls back to a cold prefill and
                                // the poisoned node is evicted.
                                Err(_) => {
                                    lock_cache(cache).restore_failed(&hit);
                                    tele.event(
                                        EventKind::PrefixPoison,
                                        session,
                                        &tenant_slug,
                                        trace,
                                        "",
                                        hit.depth as u64,
                                        0,
                                    );
                                }
                            }
                        }
                        None if cache_on => {
                            tele.event(
                                EventKind::PrefixMiss,
                                session,
                                &tenant_slug,
                                trace,
                                "",
                                0,
                                0,
                            );
                        }
                        None => {}
                    }
                    prefills.push(
                        PendingPrefill::new(session, prompt, submitted, reply)
                            .with_base(restored)
                            .with_deadline(deadline),
                    );
                }
                Err(e) => {
                    reply.send(Err(e)).ok();
                }
            }
        }
        // Deferred: applied after this window's steps execute, so a
        // step that was valid when submitted is never failed by a
        // Close that rode the same micro-batch.
        DecodeMsg::Close { session } => closes.push(session),
        DecodeMsg::Step(req) => steps.push(req),
        DecodeMsg::Shutdown => *exit = true,
    }
}
