//! Row/chunk sharding across `std::thread` scoped workers — no deps.
//!
//! Both entry points are work-gated: callers pass the minimum number of
//! items (or rows) that justifies a worker, and anything below that runs
//! inline on the caller's thread. Thread spawns cost tens of
//! microseconds, so the gates are sized for workloads in the hundreds of
//! microseconds and up; the serve path's tiny per-token GEMMs stay
//! serial while the analysis-sized matmuls and wide decode micro-batches
//! fan out.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker ceiling (cached). `FMM_THREADS` overrides detection.
pub fn max_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let n = CACHED.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::env::var("FMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, 64);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Shard `items` into contiguous chunks across scoped worker threads.
/// `f(start, chunk)` receives each chunk plus the index of its first
/// item. Runs inline when the slice is smaller than `2 * min_per_thread`
/// or only one worker would be used.
pub fn parallel_chunks<T, F>(items: &mut [T], min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let min_per = min_per_thread.max(1);
    let workers = max_threads().min(n / min_per).max(1);
    if workers <= 1 {
        f(0, items);
        return;
    }
    let per = n.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut start = 0usize;
        let mut rest = items;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            scope.spawn(move || fref(start, head));
            start += take;
        }
    });
}

/// Shard the rows of a row-major `rows x row_len` buffer across workers.
/// `f(first_row, rows_slice)` gets whole rows only — chunk boundaries
/// never split a row.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, min_rows_per_thread: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if out.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "buffer must be whole rows");
    let rows = out.len() / row_len;
    let min_rows = min_rows_per_thread.max(1);
    let workers = max_threads().min(rows / min_rows).max(1);
    if workers <= 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut row0 = 0usize;
        let mut rest = out;
        while !rest.is_empty() {
            let take_rows = per.min(rest.len() / row_len);
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut(take_rows * row_len);
            rest = tail;
            scope.spawn(move || fref(row0, head));
            row0 += take_rows;
        }
    });
}

/// Contiguous shard boundaries over weighted items: split `weights`
/// into at most `max_shards` runs of near-equal total weight. Returned
/// `(lo, hi)` ranges cover `0..weights.len()` in order with no overlap.
/// The greedy fill closes a shard once it reaches the ideal target
/// `ceil(total / shards)`, but never opens more than `max_shards`
/// shards — the final shard absorbs any remainder. This is the
/// partitioner behind [`parallel_ragged`]: when per-item work differs
/// (a 1-row decode step next to a 32-row prompt chunk), splitting by
/// *item count* would leave one worker carrying most of the rows;
/// splitting by weight keeps the shards balanced.
pub fn ragged_bounds(weights: &[usize], max_shards: usize) -> Vec<(usize, usize)> {
    let n = weights.len();
    if n == 0 {
        return Vec::new();
    }
    let shards = max_shards.min(n).max(1);
    if shards == 1 {
        return vec![(0, n)];
    }
    let total: usize = weights.iter().sum();
    let target = total.div_ceil(shards).max(1);
    let mut bounds = Vec::with_capacity(shards);
    let mut lo = 0usize;
    let mut acc = 0usize;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        if acc >= target && bounds.len() + 1 < shards {
            bounds.push((lo, i + 1));
            lo = i + 1;
            acc = 0;
        }
    }
    if lo < n {
        bounds.push((lo, n));
    }
    bounds
}

/// Shard weighted `items` into contiguous runs across scoped worker
/// threads — the ragged sibling of [`parallel_chunks`]. `weights[i]` is
/// the relative cost of `items[i]` (e.g. rows in a stacked window);
/// shard boundaries come from [`ragged_bounds`], so a mix of heavy and
/// light items still splits into near-equal work. Runs inline when the
/// total weight is under `2 * min_weight_per_thread` or only one worker
/// would be used. `f(first_item, run)` receives each run plus the index
/// of its first item.
pub fn parallel_ragged<T, F>(
    items: &mut [T],
    weights: &[usize],
    min_weight_per_thread: usize,
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = items.len();
    debug_assert_eq!(weights.len(), n, "one weight per item");
    if n == 0 {
        return;
    }
    let total: usize = weights.iter().sum();
    let min_w = min_weight_per_thread.max(1);
    let workers = max_threads().min(total / min_w).max(1);
    if workers <= 1 {
        f(0, items);
        return;
    }
    let bounds = ragged_bounds(weights, workers);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut rest = items;
        let mut consumed = 0usize;
        for &(lo, hi) in &bounds {
            debug_assert_eq!(lo, consumed, "bounds are contiguous");
            let take = hi - lo;
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            scope.spawn(move || fref(lo, head));
            consumed += take;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_item_with_correct_offsets() {
        let mut items: Vec<usize> = vec![0; 103];
        parallel_chunks(&mut items, 1, |start, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = start + off;
            }
        });
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn small_input_runs_inline() {
        let mut items = vec![0u8; 3];
        parallel_chunks(&mut items, 100, |start, chunk| {
            assert_eq!(start, 0);
            assert_eq!(chunk.len(), 3);
            chunk.iter_mut().for_each(|x| *x = 1);
        });
        assert_eq!(items, vec![1, 1, 1]);
    }

    #[test]
    fn rows_never_split() {
        let row_len = 7;
        let rows = 29;
        let mut buf = vec![0.0f32; rows * row_len];
        parallel_rows(&mut buf, row_len, 1, |first_row, chunk| {
            assert_eq!(chunk.len() % row_len, 0);
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                row.iter_mut().for_each(|x| *x = (first_row + r) as f32);
            }
        });
        for (r, row) in buf.chunks(row_len).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        parallel_chunks::<f32, _>(&mut [], 1, |_, _| panic!("no work"));
        parallel_rows(&mut [], 4, 1, |_, _| panic!("no work"));
    }

    #[test]
    fn ragged_bounds_cover_in_order_within_shard_cap() {
        // Mixed weights, several shard caps: bounds must tile 0..n in
        // order, never exceed the cap, and every shard (except possibly
        // the last) must be non-trivially loaded.
        let weights = [1usize, 32, 1, 1, 8, 1, 1, 1, 16, 4];
        let total: usize = weights.iter().sum();
        for cap in [1usize, 2, 3, 4, 8, 64] {
            let bounds = ragged_bounds(&weights, cap);
            assert!(!bounds.is_empty());
            assert!(bounds.len() <= cap.min(weights.len()), "cap {cap}: {bounds:?}");
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds.last().unwrap().1, weights.len());
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "cap {cap}: contiguous {bounds:?}");
            }
            let covered: usize =
                bounds.iter().map(|&(lo, hi)| weights[lo..hi].iter().sum::<usize>()).sum();
            assert_eq!(covered, total, "cap {cap}");
        }
        assert!(ragged_bounds(&[], 4).is_empty());
        assert_eq!(ragged_bounds(&[5], 4), vec![(0, 1)]);
        // All-equal weights degrade to the parallel_chunks split shape.
        let even = ragged_bounds(&[2usize; 8], 4);
        assert_eq!(even, vec![(0, 2), (2, 4), (4, 6), (6, 8)]);
    }

    #[test]
    fn parallel_ragged_visits_every_item_once_with_offsets() {
        // Weights chosen so a naive per-count split would be lopsided.
        let weights: Vec<usize> = (0..103).map(|i| 1 + (i * 7) % 29).collect();
        let mut items: Vec<usize> = vec![usize::MAX; weights.len()];
        parallel_ragged(&mut items, &weights, 1, |start, run| {
            for (off, x) in run.iter_mut().enumerate() {
                assert_eq!(*x, usize::MAX, "item visited twice");
                *x = start + off;
            }
        });
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn parallel_ragged_small_total_runs_inline() {
        let mut items = vec![0u8; 3];
        parallel_ragged(&mut items, &[1, 1, 1], 100, |start, run| {
            assert_eq!(start, 0);
            assert_eq!(run.len(), 3);
            run.iter_mut().for_each(|x| *x = 1);
        });
        assert_eq!(items, vec![1, 1, 1]);
        parallel_ragged::<u8, _>(&mut [], &[], 1, |_, _| panic!("no work"));
    }

    #[test]
    fn max_threads_is_positive_and_stable() {
        let a = max_threads();
        let b = max_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
