//! Row/chunk sharding across `std::thread` scoped workers — no deps.
//!
//! Both entry points are work-gated: callers pass the minimum number of
//! items (or rows) that justifies a worker, and anything below that runs
//! inline on the caller's thread. Thread spawns cost tens of
//! microseconds, so the gates are sized for workloads in the hundreds of
//! microseconds and up; the serve path's tiny per-token GEMMs stay
//! serial while the analysis-sized matmuls and wide decode micro-batches
//! fan out.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker ceiling (cached). `FMM_THREADS` overrides detection.
pub fn max_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let n = CACHED.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let n = std::env::var("FMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, 64);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Shard `items` into contiguous chunks across scoped worker threads.
/// `f(start, chunk)` receives each chunk plus the index of its first
/// item. Runs inline when the slice is smaller than `2 * min_per_thread`
/// or only one worker would be used.
pub fn parallel_chunks<T, F>(items: &mut [T], min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let min_per = min_per_thread.max(1);
    let workers = max_threads().min(n / min_per).max(1);
    if workers <= 1 {
        f(0, items);
        return;
    }
    let per = n.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut start = 0usize;
        let mut rest = items;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            rest = tail;
            scope.spawn(move || fref(start, head));
            start += take;
        }
    });
}

/// Shard the rows of a row-major `rows x row_len` buffer across workers.
/// `f(first_row, rows_slice)` gets whole rows only — chunk boundaries
/// never split a row.
pub fn parallel_rows<F>(out: &mut [f32], row_len: usize, min_rows_per_thread: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if out.is_empty() || row_len == 0 {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "buffer must be whole rows");
    let rows = out.len() / row_len;
    let min_rows = min_rows_per_thread.max(1);
    let workers = max_threads().min(rows / min_rows).max(1);
    if workers <= 1 {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(workers);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut row0 = 0usize;
        let mut rest = out;
        while !rest.is_empty() {
            let take_rows = per.min(rest.len() / row_len);
            let (head, tail) =
                std::mem::take(&mut rest).split_at_mut(take_rows * row_len);
            rest = tail;
            scope.spawn(move || fref(row0, head));
            row0 += take_rows;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_item_with_correct_offsets() {
        let mut items: Vec<usize> = vec![0; 103];
        parallel_chunks(&mut items, 1, |start, chunk| {
            for (off, x) in chunk.iter_mut().enumerate() {
                *x = start + off;
            }
        });
        for (i, &x) in items.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn small_input_runs_inline() {
        let mut items = vec![0u8; 3];
        parallel_chunks(&mut items, 100, |start, chunk| {
            assert_eq!(start, 0);
            assert_eq!(chunk.len(), 3);
            chunk.iter_mut().for_each(|x| *x = 1);
        });
        assert_eq!(items, vec![1, 1, 1]);
    }

    #[test]
    fn rows_never_split() {
        let row_len = 7;
        let rows = 29;
        let mut buf = vec![0.0f32; rows * row_len];
        parallel_rows(&mut buf, row_len, 1, |first_row, chunk| {
            assert_eq!(chunk.len() % row_len, 0);
            for (r, row) in chunk.chunks_mut(row_len).enumerate() {
                row.iter_mut().for_each(|x| *x = (first_row + r) as f32);
            }
        });
        for (r, row) in buf.chunks(row_len).enumerate() {
            assert!(row.iter().all(|&x| x == r as f32), "row {r}");
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        parallel_chunks::<f32, _>(&mut [], 1, |_, _| panic!("no work"));
        parallel_rows(&mut [], 4, 1, |_, _| panic!("no work"));
    }

    #[test]
    fn max_threads_is_positive_and_stable() {
        let a = max_threads();
        let b = max_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
    }
}
