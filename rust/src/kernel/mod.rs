//! Shared host-CPU kernel layer — the measured hot-path primitives.
//!
//! Everything the host engine does in its inner loops funnels through
//! this module so the constant factor is paid down in exactly one place:
//!
//! * [`matmul`] — blocked GEMM: B is transposed once into cache-friendly
//!   row panels (scratch-backed, no allocation when warm), rows then
//!   reduce via the unrolled [`dot`], and large products shard rows
//!   across [`parallel::parallel_rows`] workers. Small/skinny shapes
//!   fall back to the ikj loop, which is already optimal for GEMV-like
//!   sizes and keeps the decode path's per-row results independent of
//!   how many rows ride one call.
//! * Fused level-1/level-2 primitives — [`dot`], [`axpy`],
//!   [`rank1_update`] (the far-field moment update `S += φ(k)ᵀ·v`),
//!   [`vecmat_acc`] (the far-field readout `out += φ(q)·S / den`), and
//!   [`softmax_inplace`] — shared by the batch attentions in
//!   [`crate::attention`] and the incremental decode recurrence, so the
//!   two stay in numerical lockstep.
//! * [`scratch`] — a per-thread buffer arena; steady-state attention
//!   and decode calls allocate nothing.
//! * [`parallel`] — `std::thread`-scoped row/chunk sharding with
//!   work-size gates (no external deps).
//!
//! Within a chosen path, each output element reduces in an order that
//! does not depend on how many rows share the call; path selection
//! itself keys on the row count, so a row batched with ≥ 8 peers may
//! take the packed reduction where a lone GEMV row takes ikj. The
//! batched decode scheduler ([`crate::serve::decode`]) therefore
//! reproduces the scalar path within float round-off (pinned < 1e-4 by
//! the decode tests), not bitwise.

pub mod parallel;
pub mod scratch;

pub use parallel::{
    max_threads, parallel_chunks, parallel_ragged, parallel_rows, ragged_bounds,
};
pub use scratch::{scratch, Scratch};

/// Shapes with at least this many rows *and* this reduction depth take
/// the packed (transpose + dot) path; below it, ikj wins (no packing
/// overhead, GEMV-friendly).
const PACK_MIN_ROWS: usize = 8;
const PACK_MIN_DEPTH: usize = 8;

/// Minimum multiply-adds per worker before row-sharding a matmul.
const PAR_MIN_FLOPS: usize = 1 << 21;
const PAR_MIN_ROWS: usize = 16;

/// Unrolled dot product (4 independent accumulators for ILP/SIMD).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n4 = a.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < a.len() {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// `y += alpha * x`, element-wise.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yy, xx) in y.iter_mut().zip(x) {
        *yy += alpha * *xx;
    }
}

/// Rank-1 update `S += x ⊗ y` on a row-major `x.len() × y.len()` matrix
/// — the far-field moment update `S += φ(k)ᵀ·v` as one fused call.
#[inline]
pub fn rank1_update(s: &mut [f32], x: &[f32], y: &[f32]) {
    if y.is_empty() {
        return;
    }
    debug_assert_eq!(s.len(), x.len() * y.len());
    for (&xi, srow) in x.iter().zip(s.chunks_mut(y.len())) {
        axpy(xi, y, srow);
    }
}

/// `out += scale * (xᵀ S)` for row-major `S (x.len() × out.len())` — the
/// far-field readout `out += φ(q)·S / den` with `scale = 1/den`.
#[inline]
pub fn vecmat_acc(x: &[f32], s: &[f32], scale: f32, out: &mut [f32]) {
    if out.is_empty() {
        return;
    }
    debug_assert_eq!(s.len(), x.len() * out.len());
    for (&xi, srow) in x.iter().zip(s.chunks(out.len())) {
        let c = xi * scale;
        if c != 0.0 {
            axpy(c, srow, out);
        }
    }
}

/// In-place row softmax: max-shifted exp, normalized by the sum — the
/// same guard semantics as `Tensor::softmax_rows` (an all-`-inf` row
/// becomes all zeros; empty rows are untouched).
#[inline]
pub fn softmax_inplace(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if mx == f32::NEG_INFINITY {
        row.fill(0.0);
        return;
    }
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in row.iter_mut() {
        *x /= sum;
    }
}

/// Blocked GEMM: `out = a @ b` for row-major `a (m×k)`, `b (k×n)`,
/// `out (m×n)`. Overwrites `out`. Zero dimensions are fine (out is
/// zero-filled). Within a path, per-row results are independent of
/// `m`; the path itself switches at `m >= 8`, so batched and lone
/// computations of the same row agree to round-off, not bitwise.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k, "a shape");
    debug_assert_eq!(b.len(), k * n, "b shape");
    debug_assert_eq!(out.len(), m * n, "out shape");
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    if m < PACK_MIN_ROWS || k < PACK_MIN_DEPTH {
        matmul_ikj(a, b, out, k, n);
        return;
    }
    scratch::with(n * k, |bt| {
        transpose(b, bt, k, n);
        let bt: &[f32] = bt;
        let min_rows = (PAR_MIN_FLOPS / (k * n).max(1)).max(PAR_MIN_ROWS);
        parallel_rows(out, n, min_rows, |row0, rows| {
            for (ri, orow) in rows.chunks_mut(n).enumerate() {
                let i = row0 + ri;
                let arow = &a[i * k..(i + 1) * k];
                for (j, o) in orow.iter_mut().enumerate() {
                    *o = dot(arow, &bt[j * k..(j + 1) * k]);
                }
            }
        });
    });
}

/// A matrix pre-transposed into the packed panel layout [`matmul`]
/// builds in scratch on every call — pack once for operands that never
/// change (the decoder's projection/MLP/readout weights), then multiply
/// through [`matmul_prepacked`] without paying the per-call transpose.
#[derive(Debug, Clone)]
pub struct PackedMat {
    /// Column panels of the source: `bt[j*k..(j+1)*k]` is column `j`.
    bt: Vec<f32>,
    /// Rows of the source (the reduction depth).
    k: usize,
    /// Columns of the source (the output width).
    n: usize,
}

impl PackedMat {
    /// Pack row-major `b (k×n)` into column panels.
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedMat {
        assert_eq!(b.len(), k * n, "pack shape");
        let mut bt = vec![0.0f32; n * k];
        transpose(b, &mut bt, k, n);
        PackedMat { bt, k, n }
    }

    /// Rows of the source matrix (reduction depth of a multiply).
    pub fn rows(&self) -> usize {
        self.k
    }

    /// Columns of the source matrix (output width of a multiply).
    pub fn cols(&self) -> usize {
        self.n
    }

    /// Bytes held by the packed panels.
    pub fn bytes(&self) -> usize {
        self.bt.len() * std::mem::size_of::<f32>()
    }
}

/// `out = a @ b` for row-major `a (m×k)` against a pre-packed `b` —
/// [`matmul`] minus the per-call transpose. Every output element
/// reduces via [`dot`] over the packed panels for *every* `m`, so
/// per-row results are bitwise independent of how many rows share the
/// call (stronger than [`matmul`], whose ikj/packed path choice keys on
/// the row count). The batched decode scheduler leans on this: a
/// session's step computes identical bits whether it runs alone or
/// stacked in a micro-batch.
pub fn matmul_prepacked(a: &[f32], b: &PackedMat, out: &mut [f32], m: usize) {
    let (k, n) = (b.k, b.n);
    debug_assert_eq!(a.len(), m * k, "a shape");
    debug_assert_eq!(out.len(), m * n, "out shape");
    out.fill(0.0);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let bt: &[f32] = &b.bt;
    let min_rows = (PAR_MIN_FLOPS / (k * n).max(1)).max(PAR_MIN_ROWS);
    parallel_rows(out, n, min_rows, |row0, rows| {
        for (ri, orow) in rows.chunks_mut(n).enumerate() {
            let arow = &a[(row0 + ri) * k..(row0 + ri + 1) * k];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, &bt[j * k..(j + 1) * k]);
            }
        }
    });
}

/// `out = aᵀ @ b` for row-major `a (rows×d)`, `b (rows×dv)`,
/// `out (d×dv)` — the non-causal far-field moment `S = φ(K)ᵀ V` without
/// materializing the transpose (accumulates rank-1 row updates, the
/// same order the causal recurrence uses).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], rows: usize, d: usize, dv: usize) {
    debug_assert_eq!(a.len(), rows * d, "a shape");
    debug_assert_eq!(b.len(), rows * dv, "b shape");
    debug_assert_eq!(out.len(), d * dv, "out shape");
    out.fill(0.0);
    for i in 0..rows {
        rank1_update(out, &a[i * d..(i + 1) * d], &b[i * dv..(i + 1) * dv]);
    }
}

/// ikj GEMM (accumulate-by-row); skips zero `a` entries, matching the
/// seed `Tensor::matmul` semantics. Good for small/skinny shapes.
fn matmul_ikj(a: &[f32], b: &[f32], out: &mut [f32], k: usize, n: usize) {
    for (arow, orow) in a.chunks(k).zip(out.chunks_mut(n)) {
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy(av, &b[kk * n..(kk + 1) * n], orow);
        }
    }
}

/// Tiled transpose of row-major `src (k×n)` into `dst (n×k)`.
fn transpose(src: &[f32], dst: &mut [f32], k: usize, n: usize) {
    const TILE: usize = 32;
    for j0 in (0..n).step_by(TILE) {
        let j1 = (j0 + TILE).min(n);
        for k0 in (0..k).step_by(TILE) {
            let k1 = (k0 + TILE).min(k);
            for j in j0..j1 {
                for kk in k0..k1 {
                    dst[j * k + kk] = src[kk * n + j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::testutil::assert_close;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a[i * k + kk] * b[kk * n + j];
                }
                out[i * n + j] = s;
            }
        }
        out
    }

    #[test]
    fn dot_matches_reference_across_lengths() {
        let mut rng = Pcg64::seeded(0);
        for len in [0usize, 1, 3, 4, 5, 8, 31, 64, 127] {
            let a = rng.normals(len);
            let b = rng.normals(len);
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4, "len {len}");
        }
    }

    #[test]
    fn matmul_both_paths_match_naive() {
        let mut rng = Pcg64::seeded(1);
        // (m, k, n) straddling the packed-path thresholds.
        for (m, k, n) in
            [(1, 32, 32), (4, 8, 8), (8, 8, 1), (8, 8, 8), (16, 33, 7), (33, 64, 20)]
        {
            let a = rng.normals(m * k);
            let b = rng.normals(k * n);
            let mut out = vec![1.0f32; m * n]; // nonzero: matmul must overwrite
            matmul(&a, &b, &mut out, m, k, n);
            assert_close(&out, &naive(&a, &b, m, k, n), 1e-4, &format!("{m}x{k}x{n}"))
                .unwrap();
        }
    }

    #[test]
    fn matmul_zero_dims_zero_fill() {
        for (m, k, n) in [(0, 3, 4), (3, 0, 4), (3, 4, 0), (0, 0, 0)] {
            let a = vec![1.0f32; m * k];
            let b = vec![1.0f32; k * n];
            let mut out = vec![9.0f32; m * n];
            matmul(&a, &b, &mut out, m, k, n);
            assert!(out.iter().all(|&x| x == 0.0), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_rows_independent_of_batch_width() {
        // The decode scheduler relies on this: a row computed in a
        // stacked call equals the same row computed alone.
        let mut rng = Pcg64::seeded(2);
        let (m, k, n) = (24, 32, 16);
        let a = rng.normals(m * k);
        let b = rng.normals(k * n);
        let mut stacked = vec![0.0f32; m * n];
        matmul(&a, &b, &mut stacked, m, k, n);
        for i in [0usize, 7, 23] {
            let mut single = vec![0.0f32; n];
            matmul(&a[i * k..(i + 1) * k], &b, &mut single, 1, k, n);
            assert_close(&single, &stacked[i * n..(i + 1) * n], 1e-5, &format!("row {i}"))
                .unwrap();
        }
    }

    #[test]
    fn matmul_prepacked_matches_naive_and_is_row_batch_invariant() {
        let mut rng = Pcg64::seeded(7);
        for (m, k, n) in [(1usize, 3, 5), (4, 8, 8), (17, 32, 9), (33, 16, 16)] {
            let a = rng.normals(m * k);
            let b = rng.normals(k * n);
            let packed = PackedMat::pack(&b, k, n);
            assert_eq!((packed.rows(), packed.cols()), (k, n));
            let mut out = vec![1.0f32; m * n];
            matmul_prepacked(&a, &packed, &mut out, m);
            assert_close(&out, &naive(&a, &b, m, k, n), 1e-4, &format!("{m}x{k}x{n}"))
                .unwrap();
            // Bitwise row/batch invariance: each stacked row equals the
            // same row computed alone (the decode scheduler's exactness
            // story rides on this).
            for i in 0..m {
                let mut single = vec![0.0f32; n];
                matmul_prepacked(&a[i * k..(i + 1) * k], &packed, &mut single, 1);
                assert_eq!(&single[..], &out[i * n..(i + 1) * n], "row {i}");
            }
        }
    }

    #[test]
    fn matmul_prepacked_zero_dims_zero_fill() {
        for (m, k, n) in [(0usize, 3, 4), (3, 0, 4), (3, 4, 0)] {
            let a = vec![1.0f32; m * k];
            let b = vec![1.0f32; k * n];
            let packed = PackedMat::pack(&b, k, n);
            let mut out = vec![9.0f32; m * n];
            matmul_prepacked(&a, &packed, &mut out, m);
            assert!(out.iter().all(|&x| x == 0.0), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_tn_matches_explicit_transpose() {
        let mut rng = Pcg64::seeded(3);
        let (rows, d, dv) = (13, 6, 5);
        let a = rng.normals(rows * d);
        let b = rng.normals(rows * dv);
        let mut got = vec![0.0f32; d * dv];
        matmul_tn(&a, &b, &mut got, rows, d, dv);
        let mut at = vec![0.0f32; d * rows];
        transpose(&a, &mut at, rows, d);
        assert_close(&got, &naive(&at, &b, d, rows, dv), 1e-4, "matmul_tn").unwrap();
    }

    #[test]
    fn rank1_and_vecmat_roundtrip() {
        let x = [1.0f32, 2.0, -1.0];
        let y = [3.0f32, 0.5];
        let mut s = vec![0.0f32; 6];
        rank1_update(&mut s, &x, &y);
        assert_eq!(s, vec![3.0, 0.5, 6.0, 1.0, -3.0, -0.5]);
        let mut out = vec![0.0f32; 2];
        vecmat_acc(&x, &s, 0.5, &mut out);
        // xᵀ S = [3+12+3, 0.5+2+0.5] = [18, 3]; scaled by 0.5.
        assert!((out[0] - 9.0).abs() < 1e-6 && (out[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn softmax_inplace_matches_tensor_rows() {
        let mut row = vec![1.0f32, 2.0, 3.0];
        softmax_inplace(&mut row);
        let t = crate::tensor::Tensor::new(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let want = t.softmax_rows();
        for (a, b) in row.iter().zip(want.data()) {
            assert!((a - b).abs() < 1e-7);
        }
        let mut masked = vec![f32::NEG_INFINITY; 4];
        softmax_inplace(&mut masked);
        assert!(masked.iter().all(|&x| x == 0.0));
        softmax_inplace(&mut []);
    }
}
