//! Thread-local scratch-buffer arena.
//!
//! The attention and decode hot paths need short-lived f32 workspaces
//! (transposed matmul panels, phi(Q)/phi(K) images, banded score rows).
//! Allocating them per call is the single biggest constant-factor tax on
//! the host engine, so [`scratch`] checks buffers out of a per-thread
//! pool instead: steady-state callers allocate nothing — a buffer is
//! popped, resized (a memset, not a malloc, once warm), and returned to
//! the pool when its [`Scratch`] guard drops.
//!
//! Buffers come back zero-filled, so callers can accumulate into them
//! directly. Nesting is fine: each [`scratch`] call pops a distinct
//! buffer, and guards may drop in any order.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};

/// Max buffers retained per thread; beyond this, dropped guards free
/// their memory instead (bounds idle-thread footprint).
const POOL_CAP: usize = 8;

thread_local! {
    static POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// A checked-out scratch buffer; derefs to `[f32]`. Returns its storage
/// to the thread's pool on drop.
pub struct Scratch {
    buf: Vec<f32>,
}

impl Deref for Scratch {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.buf
    }
}

impl DerefMut for Scratch {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.buf
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        // try_with: TLS may already be torn down during thread exit.
        let _ = POOL.try_with(|p| {
            let mut p = p.borrow_mut();
            if p.len() < POOL_CAP {
                p.push(buf);
            }
        });
    }
}

/// Check a zero-filled buffer of `len` floats out of the thread pool.
pub fn scratch(len: usize) -> Scratch {
    let mut buf = POOL
        .try_with(|p| p.borrow_mut().pop())
        .ok()
        .flatten()
        .unwrap_or_default();
    buf.clear();
    buf.resize(len, 0.0);
    Scratch { buf }
}

/// Run `f` with a zero-filled scratch buffer of `len` floats.
pub fn with<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut g = scratch(len);
    f(&mut g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_come_back_zeroed_after_reuse() {
        {
            let mut a = scratch(16);
            a.iter_mut().for_each(|x| *x = 7.0);
        }
        let b = scratch(16);
        assert!(b.iter().all(|&x| x == 0.0));
        assert_eq!(b.len(), 16);
    }

    #[test]
    fn nested_checkouts_are_distinct() {
        let mut a = scratch(4);
        let mut b = scratch(4);
        a[0] = 1.0;
        b[0] = 2.0;
        assert_eq!((a[0], b[0]), (1.0, 2.0));
    }

    #[test]
    fn with_returns_closure_value() {
        let sum = with(8, |buf| {
            buf.iter_mut().enumerate().for_each(|(i, x)| *x = i as f32);
            buf.iter().sum::<f32>()
        });
        assert_eq!(sum, 28.0);
    }

    #[test]
    fn zero_length_is_fine() {
        let g = scratch(0);
        assert!(g.is_empty());
    }
}
