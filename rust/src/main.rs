//! `fmmformer` — the L3 coordinator CLI.
//!
//! ```text
//! fmmformer experiments                    # the paper table/figure index
//! fmmformer artifacts [--artifacts DIR]    # what is built locally
//! fmmformer train --artifact lm_fmm1_band5 --steps 300 [--eval-batches 8]
//! fmmformer eval  --artifact lm_fmm1_band5 --checkpoint runs/...ckpt.bin
//! fmmformer serve-demo [--requests 64]     # router + batcher demo
//! fmmformer decode-demo [--sessions 4 --tokens 128]  # O(1)/token streaming
//! ```

use anyhow::{anyhow, bail, Result};

use fmmformer::attention::FeatureMap;
use fmmformer::cli::Args;
use fmmformer::coordinator::{Coordinator, EXPERIMENTS};
use fmmformer::data::Split;
use fmmformer::runtime::{checkpoint, load_init_leaves, Runtime};
use fmmformer::serve::decode::{
    greedy_argmax, DecodeConfig, DecodeServer, DecodeServerConfig, HostDecoder,
};
use fmmformer::serve::front::{
    FrontClient, FrontConfig, FrontServer, TenantConfig, WIRE_VERSION,
};
use fmmformer::serve::speculative::SpeculationConfig;
use fmmformer::serve::{ServeConfig, Server};
use fmmformer::train::evaluate_params;
use fmmformer::{artifacts_dir, bench};

const ABOUT: &str = "FMMformer coordinator: train/eval/serve over AOT artifacts";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::parse(&["help", "speculate", "no-unified-planner"])?;
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "experiments" => cmd_experiments(),
        "artifacts" => cmd_artifacts(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "decode-demo" => cmd_decode_demo(&args),
        "hlo-info" => cmd_hlo_info(&args),
        _ => {
            println!("{ABOUT}\n");
            println!(
                "subcommands: experiments | artifacts | train | eval | serve-demo | \
                 decode-demo | hlo-info"
            );
            println!("common flags: --artifacts DIR  --seed N");
            println!("train: --artifact NAME --steps N [--eval-batches K] [--log-every K]");
            println!("eval:  --artifact NAME --checkpoint FILE [--batches K] [--split valid|test]");
            println!("serve-demo: [--requests N] [--max-wait-ms T]");
            println!(
                "decode-demo: [--sessions N] [--tokens N] [--layers N] [--heads N] \
                 [--d-model N] [--bandwidth K] [--kernels elu,elu_neg,tanh] \
                 [--levels L (multilevel far-field depth, 0=flat)] [--max-wait-ms T] \
                 [--max-resident N] [--spill-dir DIR] \
                 [--prompt-len N [--prefill-chunk C] [--prefill-budget N] \
                 [--prefill-budget-ms T]] [--no-unified-planner] \
                 [--prefix-cache-mb N [--prefix-stride K]] \
                 [--speculate [--draft-window K] [--draft ngram|model:LxHxD]] \
                 [--telemetry-sample N (span/event every N-th wave, 0=off)] \
                 [--trace-out FILE (dump flight-recorder JSONL at exit)]"
            );
            println!(
                "decode-demo --listen ADDR: serve the framed wire protocol \
                 [--serve-secs N (0=forever)] [--stats-interval SECS] \
                 [--tenant-rate R --tenant-burst B \
                 --tenant-streams Q] [--max-open N] [--max-queued-prompts N] \
                 [--default-deadline-ms T]"
            );
            println!(
                "decode-demo --connect ADDR: drive a listening front tier \
                 [--sessions N] [--tokens N] [--tenant NAME] [--deadline-ms T] \
                 [--trace-out FILE (pull the server trace over the wire)] \
                 (--vocab must match the server's)"
            );
            Ok(())
        }
    }
}

fn cmd_experiments() -> Result<()> {
    let mut t = bench::Table::new(
        "Experiment index (paper table/figure -> regeneration command)",
        &["id", "paper artifact", "group", "command"],
    );
    for e in EXPERIMENTS {
        t.row(vec![
            e.id.into(),
            e.paper_artifact.into(),
            e.group.into(),
            e.command.into(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .map_err(|e| anyhow!("artifacts dir {dir:?}: {e} (run `make artifacts`)"))?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            e.file_name()
                .to_str()
                .and_then(|n| n.strip_suffix(".hlo.txt"))
                .map(String::from)
        })
        .collect();
    names.sort();
    println!("{} artifacts in {dir:?}:", names.len());
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let name = args.req_str("artifact")?;
    let steps = args.usize_or("steps", 100)?;
    let eval_batches = args.usize_or("eval-batches", 0)?;
    let log_every = args.usize_or("log-every", 20)?;
    let coord = Coordinator::new(&artifacts_dir(args.get("artifacts")),
                                 args.u64_or("seed", 0)?)?;
    let out = coord.run_pipeline(name, steps, eval_batches, log_every)?;
    println!(
        "{name}: {} params, {} steps in {:.1}s ({:.2} steps/s), final loss {:.4}",
        out.n_params,
        steps,
        out.train_secs,
        steps as f64 / out.train_secs,
        out.curve.last().unwrap_or(f32::NAN)
    );
    print!("{}", bench::ascii_curve(name, &out.curve.downsample(60), 60));
    if let (Some(v), Some(t)) = (out.eval_valid, out.eval_test) {
        println!("valid: loss {:.4} metric {:.4}   test: loss {:.4} metric {:.4}",
                 v.loss, v.metric, t.loss, t.metric);
    }
    println!("checkpoint + loss CSV under {:?}", coord.runs_dir);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let name = args.req_str("artifact")?;
    let ckpt = args.req_str("checkpoint")?;
    let batches = args.usize_or("batches", 8)?;
    let split = match args.str_or("split", "test") {
        "valid" => Split::Valid,
        "test" => Split::Test,
        other => bail!("bad --split {other}"),
    };
    let coord = Coordinator::new(&artifacts_dir(args.get("artifacts")),
                                 args.u64_or("seed", 0)?)?;
    let eval_name = if name.ends_with("_eval") { name.to_string() } else { format!("{name}_eval") };
    let art = coord.rt.load(&eval_name)?;
    let leaves = checkpoint::read_leaves(std::path::Path::new(ckpt))?;
    let params =
        fmmformer::runtime::params::ParamStore::from_leaves(&coord.rt, &art.manifest, &leaves)?;
    let mut gen = coord.generator(&eval_name)?;
    let r = evaluate_params(&coord.rt, &art, &params, &mut *gen, split, batches)?;
    println!("{eval_name}: loss {:.4} metric {:.4} over {} batches", r.loss, r.metric, r.batches);
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let rt = Runtime::new(&dir)?;
    let names = ["serve_text_fmm2_b1", "serve_text_fmm2_b4", "serve_text_fmm2_b8"];
    for n in &names {
        if !rt.has_artifact(n) {
            bail!("missing artifact {n}; run `make artifacts-serve`");
        }
    }
    let base = rt.load(names[0])?;
    let leaves = if let Some(ckpt) = args.get("checkpoint") {
        checkpoint::read_leaves(std::path::Path::new(ckpt))?
    } else {
        // Untrained params: the demo exercises the serving path, not
        // accuracy. `examples/serve_demo.rs` trains first.
        load_init_leaves(rt.dir(), &rt.load("lra_text_fmm2_band5")?.manifest)
            .or_else(|_| load_init_leaves(rt.dir(), &base.manifest))?
    };

    let n_requests = args.usize_or("requests", 64)?;
    let cfg = ServeConfig {
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 5)?),
        pad_id: 0,
    };
    let server = Server::start(dir.clone(), &names, leaves, cfg)?;
    let client = server.client();
    let seq_len = base.manifest.seq_len()?;

    let mut gen = fmmformer::data::text_cls::TextCls::new(seq_len, 7);
    use fmmformer::data::TaskGen;
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for _ in 0..n_requests {
        let b = gen.batch(Split::Test, 1);
        let toks = b.tokens.row(0).to_vec();
        let c = client.clone();
        handles.push(std::thread::spawn(move || c.infer(toks)));
    }
    let mut latencies: Vec<f64> = vec![];
    for h in handles {
        let resp = h.join().map_err(|_| anyhow!("client thread panicked"))??;
        latencies.push(resp.latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    drop(client);
    let stats = server.shutdown();
    println!(
        "{n_requests} requests in {wall:.2}s -> {:.1} req/s | p50 {} p95 {} | \
         {} batches, mean occupancy {:.2}, padding waste {:.2}x",
        n_requests as f64 / wall,
        bench::fmt_time(latencies[latencies.len() / 2]),
        bench::fmt_time(latencies[latencies.len() * 95 / 100]),
        stats.batches,
        stats.mean_occupancy(),
        stats.mean_padding_waste(),
    );
    Ok(())
}

/// Streaming decode demo: host-side incremental FMM decoder, no
/// artifacts needed. N concurrent sessions greedy-decode through the
/// micro-batching scheduler; reports tokens/s, latency percentiles and
/// exactness vs the O(N²) batch forward. `--max-resident N` caps how
/// many sessions stay in RAM (idle streams page out to a session store
/// — in-memory snapshots by default, one file per stream under
/// `--spill-dir`). `--prompt-len N` opens every stream with an N-token
/// prompt ingested through the chunked prefill path (`--prefill-chunk`
/// tokens per stacked pass, `--prefill-budget` prompt tokens and
/// `--prefill-budget-ms` milliseconds of prefill work per scheduler
/// round) and reports time-to-first-token. By default all traffic
/// rides the unified ragged-batch planner (one stacked pass per wave;
/// `--no-unified-planner` restores the three-phase scheduler).
/// `--prefix-cache-mb N` turns on the radix-tree prefix cache (N MiB of
/// resident snapshots; `--prefix-stride K` sets the chunk-boundary
/// snapshot stride) so streams that share a prompt prefix fork from a
/// cached snapshot instead of re-ingesting it. `--speculate`
/// turns every stream speculative: `--draft-window K` tokens are
/// proposed per step by `--draft` (the stream's own n-gram history —
/// primed with the prompt — or a smaller draft model `model:LxHxD`)
/// and verified as one stacked step — tokens are bit-identical to the
/// plain run, only the speed changes. `--levels L` switches the
/// far field to the depth-`L` multilevel hierarchy
/// ([`fmmformer::attention::multilevel`]): coarse summaries update at
/// power-of-two strides and per-stream state grows O(log n) instead of
/// O(1) — the demo prints the summary-update and resident-bytes
/// counters when the hierarchy is active. `--telemetry-sample N` records
/// wave spans and flight-recorder wave events every N-th wave (0
/// disables wave sampling; counters are always exact) and
/// `--trace-out FILE` dumps the flight recorder as JSONL at exit.
fn cmd_decode_demo(args: &Args) -> Result<()> {
    let kernels: Vec<FeatureMap> = args
        .list_or("kernels", &["elu"])
        .iter()
        .map(|n| FeatureMap::by_name(n).ok_or_else(|| anyhow!("unknown feature map {n:?}")))
        .collect::<Result<_>>()?;
    let cfg = DecodeConfig {
        layers: args.usize_or("layers", 2)?,
        heads: args.usize_or("heads", 2)?,
        d_model: args.usize_or("d-model", 32)?,
        vocab: args.usize_or("vocab", 64)?,
        bandwidth: args.usize_or("bandwidth", 8)?,
        kernels,
        w1: args.f64_or("w1", 0.6)? as f32,
        w2: args.f64_or("w2", 0.9)? as f32,
        levels: args.usize_or("levels", 0)?,
        seed: args.u64_or("seed", 0)?,
    };
    let sessions = args.usize_or("sessions", 4)?;
    let tokens = args.usize_or("tokens", 128)?;
    let vocab = cfg.vocab;

    // Wire-client mode: drive a front tier started elsewhere with
    // `--listen`; no local model is built.
    if let Some(addr) = args.get("connect") {
        return front_connect(args, addr, sessions, tokens, vocab);
    }

    // Exactness spot check: one stream vs the batch forward.
    let model = HostDecoder::new(cfg.clone())?;
    let probe: Vec<i32> = (0..24).map(|t| (t * 7 % vocab) as i32).collect();
    let batch = model.forward_batch(&probe)?;
    let speculation = if args.has("speculate") {
        SpeculationConfig::parse(args.str_or("draft", "ngram"), &cfg)?
    } else {
        SpeculationConfig::Off
    };
    let server_cfg = DecodeServerConfig {
        max_wait: std::time::Duration::from_millis(args.u64_or("max-wait-ms", 2)?),
        max_steps: args.usize_or("max-steps", 64)?,
        batch_threshold: args.usize_or("batch-threshold", 2)?,
        max_resident_sessions: args.usize_or("max-resident", 0)?,
        speculation,
        draft_window: args.usize_or("draft-window", 4)?,
        prefill_chunk: args.usize_or("prefill-chunk", 32)?,
        prefill_budget: args.usize_or("prefill-budget", 256)?,
        prefill_budget_ms: args.f64_or("prefill-budget-ms", 0.0)?,
        unified_planner: !args.has("no-unified-planner"),
        prefix_cache_bytes: args.usize_or("prefix-cache-mb", 0)? << 20,
        prefix_snapshot_stride: args.usize_or("prefix-stride", 64)?,
        telemetry_sample: args.u64_or("telemetry-sample", 1)?,
    };

    // Wire-server mode: expose this engine over the framed TCP
    // protocol instead of running the in-process demo loop.
    if let Some(listen) = args.get("listen") {
        return front_listen(args, listen, model, server_cfg);
    }

    let server = match args.get("spill-dir") {
        Some(dir) => DecodeServer::start_with_store(
            model,
            server_cfg,
            Box::new(fmmformer::serve::session_store::DiskStore::new(
                std::path::Path::new(dir),
            )?),
        ),
        None => DecodeServer::start(model, server_cfg),
    };
    let client = server.client();
    let max_diff =
        fmmformer::serve::decode::probe_exactness(&client, &batch, &probe)?;
    println!("incremental vs batch logits over {} tokens: max |diff| {max_diff:.2e}", probe.len());

    // Closed-loop greedy decoding across concurrent sessions; with
    // --prompt-len every stream first ingests a prompt through the
    // chunked prefill path.
    let prompt_len = args.usize_or("prompt-len", 0)?;
    let t0 = std::time::Instant::now();
    let (mut lats, mut ttfts) = if prompt_len > 0 {
        let run = fmmformer::serve::prefill::run_prompted_sessions(
            &client, sessions, prompt_len, tokens, vocab,
        )?;
        (run.step_latencies, run.ttfts)
    } else {
        let lats = fmmformer::serve::decode::run_greedy_sessions(
            &client, sessions, tokens, vocab,
        )?;
        (lats, Vec::new())
    };
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    ttfts.sort_by(f64::total_cmp);
    let tele = server.telemetry();
    let stats = server.shutdown();
    dump_trace(args, &tele)?;
    if lats.is_empty() && ttfts.is_empty() {
        println!("no tokens decoded (sessions={sessions} tokens={tokens})");
        return Ok(());
    }
    if !lats.is_empty() {
        // With prompts in play the wall clock includes ingest, so the
        // rate is end-to-end — not comparable to a promptless run.
        let rate_note =
            if prompt_len > 0 { " end-to-end (wall includes prompt ingest)" } else { "" };
        println!(
            "{} sessions x {} tokens in {wall:.2}s -> {:.0} tok/s{rate_note} | \
             p50 {} p95 {} | {} micro-batches, mean {:.1} steps/batch, {} failed steps",
            sessions,
            tokens,
            lats.len() as f64 / wall,
            bench::fmt_time(lats[lats.len() / 2]),
            bench::fmt_time(lats[lats.len() * 95 / 100]),
            stats.micro_batches,
            stats.mean_micro_batch(),
            stats.failed_steps,
        );
    }
    if stats.prefills > 0 {
        println!(
            "prefill: {} prompts ({} tokens in {} chunks) | TTFT p50 {} p95 {} mean {}",
            stats.prefills,
            stats.prefill_tokens,
            stats.prefill_chunks,
            bench::fmt_time(ttfts[ttfts.len() / 2]),
            bench::fmt_time(ttfts[ttfts.len() * 95 / 100]),
            bench::fmt_time(stats.mean_ttft()),
        );
    }
    println!(
        "batched micro-steps: {:.0}% of steps via step_many ({} calls, mean width {:.1})",
        stats.batched_fraction() * 100.0,
        stats.step_many_calls,
        stats.mean_step_many_width(),
    );
    if stats.planned_rounds > 0 {
        println!(
            "planner: {} stacked passes, rows/pass min {} mean {:.1} max {} | \
             rows by kind: {} decode, {} prefill, {} verify",
            stats.planned_rounds,
            stats.rows_per_pass_min,
            stats.mean_rows_per_pass(),
            stats.rows_per_pass_max,
            stats.decode_rows,
            stats.prefill_rows,
            stats.verify_rows,
        );
    }
    if stats.spills > 0 || stats.restores > 0 {
        println!(
            "paging: {} spills / {} restores, resident peak {}, {} spilled, \
             mean restore {}",
            stats.spills,
            stats.restores,
            stats.resident_peak,
            fmmformer::util::human_bytes(stats.spilled_bytes),
            fmmformer::bench::fmt_time(stats.mean_restore_latency()),
        );
    }
    if stats.verify_steps > 0 {
        println!(
            "speculation: {} verify windows, {}/{} drafts accepted ({:.0}%), \
             {} lookahead hits",
            stats.verify_steps,
            stats.draft_accepted,
            stats.draft_proposed,
            stats.accept_rate() * 100.0,
            stats.lookahead_hits,
        );
    }
    if cfg.levels > 0 {
        println!(
            "multilevel: depth {} | {} coarse-summary updates, {} of summaries resident",
            cfg.levels,
            stats.ml_summary_updates,
            fmmformer::util::human_bytes(stats.ml_summary_bytes as u64),
        );
    }
    Ok(())
}

/// `--trace-out FILE`: dump the flight recorder as JSONL, one event per
/// line in chronological order. No-op when the flag is absent.
fn dump_trace(args: &Args, tele: &fmmformer::telemetry::Telemetry) -> Result<()> {
    let path = match args.get("trace-out") {
        Some(p) => p,
        None => return Ok(()),
    };
    let jsonl = tele.recorder().jsonl(0);
    let events = jsonl.lines().count();
    std::fs::write(path, &jsonl)
        .map_err(|e| anyhow!("writing flight-recorder trace to {path:?}: {e}"))?;
    println!("flight recorder: {events} events -> {path}");
    Ok(())
}

/// `decode-demo --listen ADDR`: serve the decode engine over the framed
/// wire protocol (admission control, deadlines, graceful drain) until
/// `--serve-secs` elapse (0 = forever). `--stats-interval SECS` prints
/// the telemetry registry snapshot document periodically while serving;
/// `--trace-out FILE` dumps the flight recorder at drain.
fn front_listen(
    args: &Args,
    addr: &str,
    model: HostDecoder,
    server_cfg: DecodeServerConfig,
) -> Result<()> {
    let front_cfg = FrontConfig {
        tenant_defaults: TenantConfig {
            rate: args.f64_or("tenant-rate", 0.0)?,
            burst: args.f64_or("tenant-burst", 16.0)?,
            max_streams: args.usize_or("tenant-streams", 0)?,
        },
        max_open_streams: args.usize_or("max-open", 0)?,
        max_queued_prompts: args.usize_or("max-queued-prompts", 0)?,
        default_deadline_ms: args.u64_or("default-deadline-ms", 0)? as u32,
        ..FrontConfig::default()
    };
    let server = match args.get("spill-dir") {
        Some(dir) => FrontServer::start_with_store(
            addr,
            model,
            server_cfg,
            front_cfg,
            Box::new(fmmformer::serve::session_store::DiskStore::new(
                std::path::Path::new(dir),
            )?),
        )?,
        None => FrontServer::start(addr, model, server_cfg, front_cfg)?,
    };
    let serve_secs = args.u64_or("serve-secs", 0)?;
    let stats_interval = args.u64_or("stats-interval", 0)?;
    let tele = server.telemetry();
    println!(
        "front tier listening on {} (wire v{WIRE_VERSION})",
        server.local_addr()
    );
    if serve_secs == 0 {
        println!("serving forever (--serve-secs 0); interrupt to stop");
    }
    let started = std::time::Instant::now();
    loop {
        let tick = if stats_interval > 0 { stats_interval } else { 3600 };
        let sleep_s = if serve_secs > 0 {
            let left = serve_secs.saturating_sub(started.elapsed().as_secs());
            if left == 0 {
                break;
            }
            tick.min(left)
        } else {
            tick
        };
        std::thread::sleep(std::time::Duration::from_secs(sleep_s));
        if stats_interval > 0 {
            println!("{}", tele.snapshot());
        }
    }
    let stats = server.shutdown();
    println!(
        "drained after {serve_secs}s: {} connections, {} bad frames, {} sheds",
        stats.connections, stats.bad_frames, stats.gate.shed_total,
    );
    dump_trace(args, &tele)?;
    Ok(())
}

/// `decode-demo --connect ADDR`: N client threads greedy-decode over
/// the wire against a listening front tier; reports tok/s, latency
/// percentiles and the server's stats document.
fn front_connect(
    args: &Args,
    addr: &str,
    sessions: usize,
    tokens: usize,
    vocab: usize,
) -> Result<()> {
    let tenant = args.str_or("tenant", "demo").to_string();
    let deadline_ms = args.u64_or("deadline-ms", 0)? as u32;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for s in 0..sessions {
        let addr = addr.to_string();
        let tenant = tenant.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut c = FrontClient::connect(&addr)?;
            let opened = c.open(&tenant, &[], deadline_ms, 0)?;
            let mut tok = (s % vocab) as i32;
            let mut lats = Vec::with_capacity(tokens);
            for _ in 0..tokens {
                let t = std::time::Instant::now();
                let reply = c.step(opened.stream, tok, deadline_ms)?;
                lats.push(t.elapsed().as_secs_f64());
                tok = greedy_argmax(&reply.logits);
            }
            c.close_stream(opened.stream)?;
            Ok(lats)
        }));
    }
    let mut lats = Vec::new();
    for h in handles {
        lats.extend(h.join().map_err(|_| anyhow!("wire client thread panicked"))??);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    if lats.is_empty() {
        println!("no tokens decoded (sessions={sessions} tokens={tokens})");
        return Ok(());
    }
    println!(
        "{sessions} wire sessions x {tokens} tokens in {wall:.2}s -> {:.0} tok/s | \
         step p50 {} p95 {}",
        lats.len() as f64 / wall,
        bench::fmt_time(lats[lats.len() / 2]),
        bench::fmt_time(lats[lats.len() * 95 / 100]),
    );
    let mut c = FrontClient::connect(addr)?;
    println!("server stats: {}", c.stats()?);
    if let Some(path) = args.get("trace-out") {
        // Over the wire: the server's flight recorder, newest events.
        let jsonl = c.trace(0)?;
        let events = jsonl.lines().count();
        std::fs::write(path, &jsonl)
            .map_err(|e| anyhow!("writing flight-recorder trace to {path:?}: {e}"))?;
        println!("flight recorder: {events} events -> {path}");
    }
    Ok(())
}

/// L2 profiling: instruction mix of an artifact's HLO (EXPERIMENTS §Perf).
fn cmd_hlo_info(args: &Args) -> Result<()> {
    let name = args.req_str("artifact")?;
    let dir = artifacts_dir(args.get("artifacts"));
    let info = fmmformer::runtime::hlo_info::HloInfo::load(
        &dir.join(format!("{name}.hlo.txt")))?;
    println!("{name}: {} instructions, {} fusions, {} while loops, ~{:.2} GFLOP in dots",
             info.total, info.fusions, info.whiles, info.dot_flops as f64 / 1e9);
    for (op, n) in info.top(12) {
        println!("  {op:<28} {n}");
    }
    Ok(())
}
