//! Command-line parsing (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean
//! switches, typed accessors with defaults, required args, and generated
//! usage text. Every binary, example and bench in the crate parses with
//! this.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Declarative flag spec for usage/help output.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_switch: bool,
}

/// Parsed arguments: positionals + `--key value` pairs.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    specs: Vec<FlagSpec>,
    program: String,
}

impl Args {
    /// Parse from an explicit iterator (tests) — first item is argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(
        argv: I,
        switch_names: &[&str],
    ) -> Result<Args> {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        let mut pending: Option<String> = None;
        for a in it {
            if let Some(key) = pending.take() {
                args.flags.insert(key, a);
                continue;
            }
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if switch_names.contains(&body) {
                    args.switches.push(body.to_string());
                } else {
                    pending = Some(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        if let Some(key) = pending {
            bail!("flag --{key} expects a value");
        }
        Ok(args)
    }

    /// Parse the process arguments. `switch_names` lists boolean flags
    /// (present/absent, no value). `--bench` is always a switch: cargo
    /// appends it when running `cargo bench` targets.
    pub fn parse(switch_names: &[&str]) -> Result<Args> {
        let mut names = switch_names.to_vec();
        names.push("bench");
        Self::parse_from(std::env::args(), &names)
    }

    pub fn program(&self) -> &str {
        &self.program
    }

    /// Register a spec (for `usage()`); returns self for chaining.
    pub fn describe(mut self, specs: Vec<FlagSpec>) -> Args {
        self.specs = specs;
        self
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req_str(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required flag --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} wants a number, got {v:?}")),
        }
    }

    /// Comma-separated list flag.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect(),
        }
    }

    /// Render usage text from the registered specs.
    pub fn usage(&self, about: &str) -> String {
        let mut s = format!("{about}\n\nUSAGE: {} [flags]\n\nFLAGS:\n", self.program);
        for spec in &self.specs {
            let d = match (spec.is_switch, spec.default) {
                (true, _) => " (switch)".to_string(),
                (false, Some(d)) => format!(" [default: {d}]"),
                (false, None) => " (required)".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", spec.name, spec.help, d));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_switches_positionals() {
        let a = Args::parse_from(argv("prog train --steps 100 --lr=0.001 --verbose copy128"),
                                 &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["train", "copy128"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_requireds() {
        let a = Args::parse_from(argv("prog"), &[]).unwrap();
        assert_eq!(a.usize_or("steps", 42).unwrap(), 42);
        assert_eq!(a.str_or("name", "x"), "x");
        assert!(a.req_str("out").is_err());
    }

    #[test]
    fn dangling_flag_is_error() {
        assert!(Args::parse_from(argv("prog --steps"), &[]).is_err());
    }

    #[test]
    fn bad_types_are_errors() {
        let a = Args::parse_from(argv("prog --steps many"), &[]).unwrap();
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn list_flag_splits() {
        let a = Args::parse_from(argv("prog --variants a,b,,c"), &[]).unwrap();
        assert_eq!(a.list_or("variants", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.list_or("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn usage_renders() {
        let a = Args::parse_from(argv("prog"), &[]).unwrap().describe(vec![
            FlagSpec { name: "steps", help: "train steps", default: Some("100"), is_switch: false },
            FlagSpec { name: "quick", help: "fast mode", default: None, is_switch: true },
        ]);
        let u = a.usage("demo");
        assert!(u.contains("--steps") && u.contains("[default: 100]") && u.contains("(switch)"));
    }
}
