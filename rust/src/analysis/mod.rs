//! Attention-structure analysis (Figs. 1, 3, 8).
//!
//! Consumes N×N attention maps produced by the `attn_weights` /
//! `fmm_maps` artifacts and runs the paper's structural studies in pure
//! Rust: singular-value spectra, ε-rank histograms after band removal
//! (Fig. 3), and heatmap dumps (Fig. 8 / Fig. 1).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::linalg::{eps_rank, singular_values, strip_band};
use crate::tensor::Tensor;

/// Fig. 3 row: rank distribution of `A - band_k(A)` for one bandwidth.
#[derive(Debug, Clone)]
pub struct RankStudy {
    pub bandwidth: usize,
    pub ranks: Vec<usize>,
}

impl RankStudy {
    pub fn mean_rank(&self) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        self.ranks.iter().sum::<usize>() as f64 / self.ranks.len() as f64
    }

    pub fn median_rank(&self) -> usize {
        if self.ranks.is_empty() {
            return 0;
        }
        let mut s = self.ranks.clone();
        s.sort_unstable();
        s[s.len() / 2]
    }

    /// Histogram over `bins` equal-width buckets up to `max`.
    pub fn histogram(&self, bins: usize, max: usize) -> Vec<usize> {
        let mut h = vec![0usize; bins];
        for &r in &self.ranks {
            let b = (r * bins / max.max(1)).min(bins - 1);
            h[b] += 1;
        }
        h
    }
}

/// The Fig. 3 experiment: for each bandwidth, strip the band from every
/// attention map and measure the ε-rank (absolute threshold 1e-6, the
/// paper's Fig. 3 caption convention).
pub fn rank_study(maps: &[Tensor], bandwidths: &[usize], eps: f32) -> Vec<RankStudy> {
    bandwidths
        .iter()
        .map(|&bw| {
            let ranks = maps
                .iter()
                .map(|a| {
                    let far = if bw == 0 { a.clone() } else { strip_band(a, bw) };
                    let sv = singular_values(&far);
                    eps_rank(&sv, eps, false)
                })
                .collect();
            RankStudy { bandwidth: bw, ranks }
        })
        .collect()
}

/// Singular-value spectrum of one map (Fig. 3 top-right panel).
pub fn spectrum(map: &Tensor) -> Vec<f32> {
    singular_values(map)
}

/// Write a matrix as a binary-portable PGM heatmap (Figs. 1 & 8). Values
/// are normalized to [0, max] -> [255, 0] (dark = high attention, like
/// the paper's colormaps inverted for print).
pub fn write_pgm(path: &Path, map: &Tensor) -> Result<()> {
    let [h, w] = map.shape()[..] else { anyhow::bail!("heatmap needs 2-D") };
    let mx = map.data().iter().cloned().fold(0.0f32, f32::max).max(1e-12);
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    writeln!(f, "P5\n{w} {h}\n255")?;
    let bytes: Vec<u8> = map
        .data()
        .iter()
        .map(|&v| 255 - ((v / mx).clamp(0.0, 1.0) * 255.0) as u8)
        .collect();
    f.write_all(&bytes)?;
    Ok(())
}

/// Coarse ASCII rendering (terminal-friendly Fig. 8).
pub fn ascii_heatmap(map: &Tensor, cells: usize) -> String {
    let [h, w] = map.shape()[..] else { panic!("heatmap needs 2-D") };
    let shades = [' ', '.', ':', '+', '*', '#', '@'];
    let mx = map.data().iter().cloned().fold(0.0f32, f32::max).max(1e-12);
    let mut out = String::new();
    for cy in 0..cells {
        for cx in 0..cells {
            // Max-pool the cell (peaks matter in attention maps).
            let y0 = cy * h / cells;
            let y1 = ((cy + 1) * h / cells).max(y0 + 1);
            let x0 = cx * w / cells;
            let x1 = ((cx + 1) * w / cells).max(x0 + 1);
            let mut v = 0.0f32;
            for y in y0..y1 {
                for x in x0..x1 {
                    v = v.max(map.at(y, x));
                }
            }
            let idx = ((v / mx).clamp(0.0, 1.0) * (shades.len() - 1) as f32).round() as usize;
            out.push(shades[idx]);
        }
        out.push('\n');
    }
    out
}

/// Fraction of attention mass within the bandwidth-k band — quantifies
/// "how near-field is this head" (Fig. 8 discussion).
pub fn band_mass_fraction(map: &Tensor, bandwidth: usize) -> f32 {
    let total = map.data().iter().sum::<f32>().max(1e-12);
    let near = crate::linalg::keep_band(map, bandwidth).data().iter().sum::<f32>();
    near / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention;
    use crate::rng::Pcg64;

    fn softmax_map(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let q = Tensor::randn(&[n, 8], &mut rng);
        let k = Tensor::randn(&[n, 8], &mut rng);
        attention::softmax_attention_weights(&q, &k, false)
    }

    #[test]
    fn rank_decreases_with_bandwidth() {
        // The paper's Fig. 3 claim: rank(A - D) shrinks as D's bandwidth
        // grows (monotone on average).
        let maps: Vec<Tensor> = (0..4).map(|s| softmax_map(48, s)).collect();
        let studies = rank_study(&maps, &[0, 5, 10, 20], 1e-6);
        let means: Vec<f64> = studies.iter().map(|s| s.mean_rank()).collect();
        for w in means.windows(2) {
            assert!(w[1] <= w[0] + 0.5, "{means:?}");
        }
        assert_eq!(studies[0].ranks.len(), 4);
    }

    #[test]
    fn attention_maps_have_decaying_spectrum() {
        let sv = spectrum(&softmax_map(48, 7));
        assert!(sv[0] > 5.0 * sv[sv.len() / 2], "{:?}", &sv[..8]);
    }

    #[test]
    fn band_mass_reaches_one_at_full_bandwidth() {
        let m = softmax_map(16, 1);
        let f0 = band_mass_fraction(&m, 0);
        let f5 = band_mass_fraction(&m, 5);
        let f15 = band_mass_fraction(&m, 15);
        assert!(f0 < f5 && f5 < f15);
        assert!((f15 - 1.0).abs() < 1e-4);
    }

    #[test]
    fn pgm_and_ascii_render() {
        let m = softmax_map(32, 2);
        let dir = std::env::temp_dir().join(format!("fmm_pgm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.pgm");
        write_pgm(&p, &m).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n32 32\n255\n"));
        assert_eq!(bytes.len(), "P5\n32 32\n255\n".len() + 32 * 32);
        let art = ascii_heatmap(&m, 8);
        assert_eq!(art.lines().count(), 8);
        std::fs::remove_dir_all(&dir).ok();
    }
}
