"""L2 — whole-train-step functions lowered to single AOT artifacts.

The Rust trainer never computes math on the host: one ``execute_b`` call
per step runs

    step(params…, m…, v…, t, tokens, targets)
        -> (params'…, m'…, v'…, loss)

entirely in-graph — cross-entropy, reverse-mode grads (through the Pallas
kernels' custom_vjps), global-norm clipping, linear-warmup Adam. Flat leaf
lists (order defined by ``model.param_leaves``) are the ABI; the manifest
written by ``aot.py`` records it.

Hyper-parameters (paper App. 9): Adam, base lr 2.5e-4, 2000-step warmup
(scaled down alongside the step budgets — see DESIGN.md §3), grad-clip 1.0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import model as M

#: LM targets with this id contribute no loss (padding / context-only).
IGNORE_ID = -1


@dataclasses.dataclass(frozen=True)
class OptConfig:
    """Adam + schedule hyper-parameters (baked into the artifact)."""

    lr: float = 2.5e-4
    warmup_steps: int = 200
    beta1: float = 0.9
    beta2: float = 0.98
    eps: float = 1e-9
    clip_norm: float = 1.0

    def to_meta(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(cfg: M.ModelConfig, params, tokens, targets):
    """Mean next-token cross-entropy over positions with target != IGNORE_ID.

    ``tokens``/``targets`` are (B, N) int32; the data pipeline does the
    shift (targets[i] = tokens[i+1]) so the artifact stays shape-simple.
    """
    logits = M.forward(cfg, params, tokens)                      # (B, N, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = (targets != IGNORE_ID).astype(logits.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def cls_loss_and_correct(cfg: M.ModelConfig, params, tokens, labels):
    """Classifier cross-entropy + number of correct argmax predictions."""
    logits = M.forward(cfg, params, tokens)                      # (B, C)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return nll.mean(), correct.sum()


def _loss_fn(cfg: M.ModelConfig):
    if cfg.num_classes is None:
        return lambda p, x, y: lm_loss(cfg, p, x, y)
    return lambda p, x, y: cls_loss_and_correct(cfg, p, x, y)[0]


# ---------------------------------------------------------------------------
# Adam with linear warmup + global-norm clipping (in-graph)
# ---------------------------------------------------------------------------

def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))


def adam_update(opt: OptConfig, params, m, v, grads, t):
    """One Adam step over *lists of leaves*. ``t`` is the 1-based step
    count as an f32 scalar (bias correction needs it as a float)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-12))
    lr = opt.lr * jnp.minimum(1.0, t / max(opt.warmup_steps, 1))
    bc1 = 1.0 - opt.beta1 ** t
    bc2 = 1.0 - opt.beta2 ** t

    def upd(p, m_, v_, g):
        g = g * scale
        m_ = opt.beta1 * m_ + (1.0 - opt.beta1) * g
        v_ = opt.beta2 * v_ + (1.0 - opt.beta2) * g * g
        p = p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + opt.eps)
        return p, m_, v_

    out = [upd(p, m_, v_, g) for p, m_, v_, g in zip(params, m, v, grads)]
    return [o[0] for o in out], [o[1] for o in out], [o[2] for o in out]


# ---------------------------------------------------------------------------
# Flat-ABI step builders (the functions aot.py lowers)
# ---------------------------------------------------------------------------

def make_train_step(cfg: M.ModelConfig, opt: OptConfig, template: dict):
    """Build ``step(*leaves3, t, tokens, targets) -> (*leaves3, loss)``.

    ``template`` is an example params pytree (defines structure only).
    ``adam_update``'s pytree maps run on *lists of leaves* directly, so the
    flat ABI and the internal pytree agree by construction.
    """
    n = len(M.param_leaves(template))
    loss_fn = _loss_fn(cfg)

    def step(*args):
        p_leaves = list(args[:n])
        m_leaves = list(args[n:2 * n])
        v_leaves = list(args[2 * n:3 * n])
        t, tokens, targets = args[3 * n], args[3 * n + 1], args[3 * n + 2]

        params = M.unflatten_like(template, p_leaves)
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, targets)
        g_leaves = [leaf for _, leaf in M.param_leaves(grads)]
        new_p, new_m, new_v = adam_update(opt, p_leaves, m_leaves, v_leaves,
                                          g_leaves, t)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return step, n


def make_eval_step(cfg: M.ModelConfig, template: dict):
    """Build ``eval(*params, tokens, targets) -> (loss_sum_weight, ...)``.

    LM: returns (masked nll sum, token count) so the host can aggregate
    exact corpus perplexity across batches. Classifier: (nll mean * B,
    correct count) for exact accuracy.
    """
    n = len(M.param_leaves(template))

    def step(*args):
        params = M.unflatten_like(template, list(args[:n]))
        tokens, targets = args[n], args[n + 1]
        if cfg.num_classes is None:
            logits = M.forward(cfg, params, tokens)
            logp = jax.nn.log_softmax(logits, axis=-1)
            tgt = jnp.maximum(targets, 0)
            nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
            mask = (targets != IGNORE_ID).astype(logits.dtype)
            return (nll * mask).sum(), mask.sum()
        loss, correct = cls_loss_and_correct(cfg, params, tokens, targets)
        b = jnp.asarray(tokens.shape[0], jnp.float32)
        return loss * b, correct

    return step, n


def make_predict(cfg: M.ModelConfig, template: dict):
    """Build ``predict(*params, tokens) -> logits`` (the serving artifact)."""
    n = len(M.param_leaves(template))

    def step(*args):
        params = M.unflatten_like(template, list(args[:n]))
        return (M.forward(cfg, params, args[n]),)

    return step, n
