"""AOT compiler — lowers every registered artifact to HLO text + manifest.

This is the *only* entry point where Python runs in the build: it traces
the L2 functions (which call the L1 Pallas kernels), lowers to StableHLO,
converts to an XlaComputation, and writes

    artifacts/<name>.hlo.txt     — HLO **text** (interchange format; the
                                   xla crate's XLA 0.5.1 rejects jax>=0.5
                                   serialized protos with 64-bit ids, the
                                   text parser reassigns ids — see
                                   /opt/xla-example/README.md)
    artifacts/<name>.json        — manifest: ordered typed inputs/outputs,
                                   param table, model/opt/task metadata
    artifacts/<name>.params.bin  — seeded initial parameters (train only)

Usage:
    python -m compile.aot --out-dir ../artifacts --group core
    python -m compile.aot --out-dir ../artifacts --only 'copy128_.*' --force
    python -m compile.aot --list

Idempotent: existing outputs are skipped unless --force (so `make
artifacts` is a no-op when nothing changed; Make handles input staleness).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import analysis, binfmt
from . import model as M
from . import train_step as T
from .configs import GROUPS, ArtifactSpec, build_registry


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_str(x) -> str:
    return {"float32": "f32", "int32": "i32"}[str(x.dtype)]


def _sig_entry(name, role, aval):
    return dict(name=name, role=role, shape=[int(d) for d in aval.shape],
                dtype=_dtype_str(aval))


def _param_entries(leaves, role, suffix=""):
    return [_sig_entry(n + suffix, role, a) for n, a in leaves]


def _spec_of(x):
    return jax.ShapeDtypeStruct(np.shape(x), x.dtype)


def build_artifact(spec: ArtifactSpec):
    """Trace + lower one artifact. Returns (hlo_text, manifest, init_leaves)."""
    manifest = dict(name=spec.name, group=spec.group, kind=spec.kind,
                    batch=spec.batch, seed=spec.seed)
    if spec.task is not None:
        manifest["task"] = spec.task

    if spec.kind == "attn_fwdbwd":
        fb = spec.fwdbwd
        fn = analysis.make_attn_fwdbwd(
            fb["variant"], bandwidth=fb.get("bandwidth", 30),
            kernels_list=tuple(fb.get("kernels", ("elu",))),
            causal=False, impl=fb.get("impl", "pallas"))
        qkv = jax.ShapeDtypeStruct((fb["n"], fb["d"]), jnp.float32)
        lowered = jax.jit(fn, keep_unused=True).lower(qkv, qkv, qkv)
        manifest["fwdbwd"] = {k: (list(v) if isinstance(v, tuple) else v)
                              for k, v in fb.items()}
        manifest["inputs"] = [
            dict(name=x, role="input", shape=[fb["n"], fb["d"]], dtype="f32")
            for x in ("q", "k", "v")]
        manifest["outputs"] = (
            [dict(name="out_mean", role="output", shape=[], dtype="f32")] +
            [dict(name=f"d{x}", role="output", shape=[fb["n"], fb["d"]],
                  dtype="f32") for x in ("q", "k", "v")])
        return to_hlo_text(lowered), manifest, None

    cfg = spec.model
    manifest["model"] = cfg.to_meta()
    manifest["param_key"] = spec.param_key
    params = M.init_params(cfg, seed=spec.seed)
    leaves = M.param_leaves(params)
    manifest["params"] = _param_entries(leaves, "param")
    b, n = spec.batch, cfg.seq_len
    tokens = jax.ShapeDtypeStruct((b, n), jnp.int32)

    if spec.kind == "train_step":
        step, nleaves = T.make_train_step(cfg, spec.opt, params)
        manifest["opt"] = spec.opt.to_meta()
        t_spec = jax.ShapeDtypeStruct((), jnp.float32)
        targets = jax.ShapeDtypeStruct(
            (b, n) if cfg.num_classes is None else (b,), jnp.int32)
        specs = ([_spec_of(a) for _, a in leaves] * 3 + [t_spec, tokens, targets])
        lowered = jax.jit(step, keep_unused=True).lower(*specs)
        manifest["inputs"] = (
            _param_entries(leaves, "param")
            + _param_entries(leaves, "opt_m", ".m")
            + _param_entries(leaves, "opt_v", ".v")
            + [dict(name="t", role="step", shape=[], dtype="f32"),
               _sig_entry("tokens", "tokens", tokens),
               _sig_entry("targets", "targets", targets)])
        manifest["outputs"] = (
            _param_entries(leaves, "param")
            + _param_entries(leaves, "opt_m", ".m")
            + _param_entries(leaves, "opt_v", ".v")
            + [dict(name="loss", role="loss", shape=[], dtype="f32")])
        manifest["init_params"] = f"{spec.name}.params.bin"
        return to_hlo_text(lowered), manifest, leaves

    if spec.kind == "eval_step":
        step, _ = T.make_eval_step(cfg, params)
        targets = jax.ShapeDtypeStruct(
            (b, n) if cfg.num_classes is None else (b,), jnp.int32)
        specs = [_spec_of(a) for _, a in leaves] + [tokens, targets]
        lowered = jax.jit(step, keep_unused=True).lower(*specs)
        out_names = (("nll_sum", "token_count") if cfg.num_classes is None
                     else ("loss_sum", "correct"))
        manifest["inputs"] = (
            _param_entries(leaves, "param")
            + [_sig_entry("tokens", "tokens", tokens),
               _sig_entry("targets", "targets", targets)])
        manifest["outputs"] = [dict(name=o, role="metric", shape=[], dtype="f32")
                               for o in out_names]
        return to_hlo_text(lowered), manifest, None

    if spec.kind == "predict":
        step, _ = T.make_predict(cfg, params)
        specs = [_spec_of(a) for _, a in leaves] + [tokens]
        lowered = jax.jit(step, keep_unused=True).lower(*specs)
        out_shape = ([b, n, cfg.vocab_size] if cfg.num_classes is None
                     else [b, cfg.num_classes])
        manifest["inputs"] = (_param_entries(leaves, "param")
                              + [_sig_entry("tokens", "tokens", tokens)])
        manifest["outputs"] = [dict(name="logits", role="logits",
                                    shape=out_shape, dtype="f32")]
        return to_hlo_text(lowered), manifest, None

    if spec.kind == "attn_weights":
        fn, _ = analysis.make_attn_weights(cfg, params)
        specs = [_spec_of(a) for _, a in leaves] + [tokens]
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        shape = [b, cfg.n_layers, cfg.n_heads, n, n]
        manifest["inputs"] = (_param_entries(leaves, "param")
                              + [_sig_entry("tokens", "tokens", tokens)])
        manifest["outputs"] = [dict(name="attn", role="maps", shape=shape,
                                    dtype="f32")]
        return to_hlo_text(lowered), manifest, None

    if spec.kind == "fmm_maps":
        fn, _ = analysis.make_fmm_maps(cfg, params)
        specs = [_spec_of(a) for _, a in leaves] + [tokens]
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        shape = [b, cfg.n_layers, cfg.n_heads, n, n]
        manifest["inputs"] = (_param_entries(leaves, "param")
                              + [_sig_entry("tokens", "tokens", tokens)])
        manifest["outputs"] = [
            dict(name="near", role="maps", shape=shape, dtype="f32"),
            dict(name="far", role="maps", shape=shape, dtype="f32")]
        return to_hlo_text(lowered), manifest, None

    raise ValueError(f"unknown artifact kind {spec.kind!r}")


def emit(spec: ArtifactSpec, out_dir: str, force: bool) -> str:
    hlo_path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
    man_path = os.path.join(out_dir, f"{spec.name}.json")
    if not force and os.path.exists(hlo_path) and os.path.exists(man_path):
        return "skip"
    t0 = time.time()
    hlo, manifest, init_leaves = build_artifact(spec)
    with open(hlo_path, "w") as f:
        f.write(hlo)
    if init_leaves is not None:
        binfmt.write_params(os.path.join(out_dir, manifest["init_params"]),
                            init_leaves)
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    return f"{time.time() - t0:.1f}s {len(hlo) // 1024}KiB"


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--group", action="append", choices=GROUPS,
                   help="restrict to group(s); default: all")
    p.add_argument("--only", help="regex on artifact names")
    p.add_argument("--impl", default=None, choices=("pallas", "jnp"),
                   help="override the per-group kernel-impl defaults")
    p.add_argument("--force", action="store_true")
    p.add_argument("--list", action="store_true")
    args = p.parse_args(argv)

    reg = build_registry(impl=args.impl)
    names = sorted(reg)
    if args.group:
        names = [n for n in names if reg[n].group in args.group]
    if args.only:
        rx = re.compile(args.only)
        names = [n for n in names if rx.search(n)]

    if args.list:
        for n in names:
            s = reg[n]
            print(f"{s.group:9s} {s.kind:12s} {n}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    for i, n in enumerate(names):
        status = emit(reg[n], args.out_dir, args.force)
        print(f"[{i + 1}/{len(names)}] {n}: {status}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
