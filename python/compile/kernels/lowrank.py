"""Pallas far-field (low-rank kernelized linear) attention kernels.

Far-field attention is the sum over feature maps phi_l of

    phi_l(Q) (phi_l(K)^T V) / (phi_l(Q) · sum_j phi_l(k_j))      (paper eq. 9)

— a rank-1 normalized attention per map; r maps give a rank-r far field
(paper Prop. 1). Two schedules:

Non-causal (two kernels, both O(N)):
  1. ``_reduce_kernel`` — grid over K/V blocks, *sequentially accumulating*
     the multipole moments ``S = phi(K)^T V`` (d_phi × dv) and
     ``z = sum phi(K)`` into a revisited output block. On TPU the grid is
     executed in order, so the accumulate-into-output pattern is exact;
     the interpret path matches.
  2. ``_apply_kernel`` — grid over Q blocks: ``out = phi(q)S / (phi(q)·z)``.
     S and z stay resident in VMEM across all steps (tiny: d_phi·dv words).

Causal (one kernel): sequential grid over sequence blocks carrying the
running ``(S, z)`` prefix state in VMEM scratch — scratch persists across
grid steps on sequential TPU grids. Within a block the causal part is a
(B × B) masked product; across blocks it is the carried state. This is the
TPU analogue of the GPU chunked-scan linear attention.

VMEM per grid step: B·(d_phi + dv) + d_phi·dv + B·B (causal within-block
scores) — e.g. B=128, d=dv=64: ~0.13 MiB.

The feature maps are applied by the *wrapper* (cheap elementwise VPU work
that XLA fuses into the surrounding graph); the kernels take phi(Q),
phi(K) directly. Padded K rows must contribute nothing, so the wrapper
zeroes phi(K) beyond row N (phi(0) != 0 for the elu maps!).

Backward: custom_vjp with reverse via ``jax.vjp`` of the jnp reference
(O(N) math). See banded.py for the rationale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import jnp_fast, ref
from .feature_maps import get_feature_maps

DEFAULT_BLOCK = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Non-causal: reduce (moments) + apply
# ---------------------------------------------------------------------------

def _reduce_kernel(phik_ref, v_ref, s_ref, z_ref):
    """Accumulate S += phi(K)_b^T V_b and z += sum phi(K)_b over the grid."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    phik = phik_ref[...]                       # (B, d_phi)
    s_ref[...] += jnp.dot(phik.T, v_ref[...],  # MXU (d_phi, dv)
                          preferred_element_type=jnp.float32).astype(s_ref.dtype)
    z_ref[...] += jnp.sum(phik, axis=0, keepdims=True).astype(z_ref.dtype)


def _apply_kernel(phiq_ref, s_ref, z_ref, o_ref, *, eps: float):
    """out = phi(q) S / guard(phi(q) · z)."""
    phiq = phiq_ref[...]                       # (B, d_phi)
    num = jnp.dot(phiq, s_ref[...], preferred_element_type=jnp.float32)
    den = jnp.dot(phiq, z_ref[...].T, preferred_element_type=jnp.float32)  # (B, 1)
    den = jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)
    o_ref[...] = (num / den).astype(o_ref.dtype)


def linear_attention_one_noncausal_fwd(phi_q, phi_k, v, *, block: int = DEFAULT_BLOCK):
    """One feature map, non-causal. phi_q, phi_k: (N, d_phi); v: (N, dv)."""
    n, dphi = phi_q.shape
    dv = v.shape[-1]
    b = min(_round_up(max(block, 8), 8), _round_up(n, 8))
    n_pad = _round_up(n, b)
    grid = n_pad // b

    # Zero-pad: padded phi_k rows are zero => contribute nothing to S, z.
    pq = jnp.pad(phi_q, ((0, n_pad - n), (0, 0)))
    pk = jnp.pad(phi_k, ((0, n_pad - n), (0, 0)))
    vp = jnp.pad(v, ((0, n_pad - n), (0, 0)))

    s, z = pl.pallas_call(
        _reduce_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((b, dphi), lambda j: (j, 0)),
                  pl.BlockSpec((b, dv), lambda j: (j, 0))],
        out_specs=[pl.BlockSpec((dphi, dv), lambda j: (0, 0)),
                   pl.BlockSpec((1, dphi), lambda j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((dphi, dv), jnp.float32),
                   jax.ShapeDtypeStruct((1, dphi), jnp.float32)],
        interpret=True,
    )(pk, vp)

    out = pl.pallas_call(
        functools.partial(_apply_kernel, eps=ref.DEN_EPS),
        grid=(grid,),
        in_specs=[pl.BlockSpec((b, dphi), lambda i: (i, 0)),
                  pl.BlockSpec((dphi, dv), lambda i: (0, 0)),
                  pl.BlockSpec((1, dphi), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((b, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, dv), phi_q.dtype),
        interpret=True,
    )(pq, s.astype(phi_q.dtype), z.astype(phi_q.dtype))
    return out[:n]


# ---------------------------------------------------------------------------
# Causal: sequential grid carrying (S, z) prefix state in scratch
# ---------------------------------------------------------------------------

def _causal_kernel(phiq_ref, phik_ref, v_ref, o_ref, s_ref, z_ref, *,
                   block: int, eps: float):
    """Chunked causal linear attention; scratch (s, z) is the prefix state."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    phiq = phiq_ref[...]                       # (B, d_phi)
    phik = phik_ref[...]
    v = v_ref[...]                             # (B, dv)

    # Cross-block term: everything strictly before this block.
    num = jnp.dot(phiq, s_ref[...], preferred_element_type=jnp.float32)
    den = jnp.dot(phiq, z_ref[...].T, preferred_element_type=jnp.float32)  # (B,1)

    # Within-block causal term (includes the diagonal).
    a = jnp.dot(phiq, phik.T, preferred_element_type=jnp.float32)          # (B,B)
    rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(cols <= rows, a, 0.0)
    num += jnp.dot(a, v, preferred_element_type=jnp.float32)
    den += jnp.sum(a, axis=-1, keepdims=True)

    den = jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)
    o_ref[...] = (num / den).astype(o_ref.dtype)

    # Fold this block into the prefix state for the next grid step.
    s_ref[...] += jnp.dot(phik.T, v, preferred_element_type=jnp.float32).astype(s_ref.dtype)
    z_ref[...] += jnp.sum(phik, axis=0, keepdims=True).astype(z_ref.dtype)


def linear_attention_one_causal_fwd(phi_q, phi_k, v, *, block: int = DEFAULT_BLOCK):
    """One feature map, causal. Chunked-scan schedule (module docstring)."""
    n, dphi = phi_q.shape
    dv = v.shape[-1]
    b = min(_round_up(max(block, 8), 8), _round_up(n, 8))
    n_pad = _round_up(n, b)
    grid = n_pad // b

    pq = jnp.pad(phi_q, ((0, n_pad - n), (0, 0)))
    pk = jnp.pad(phi_k, ((0, n_pad - n), (0, 0)))
    vp = jnp.pad(v, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_causal_kernel, block=b, eps=ref.DEN_EPS),
        grid=(grid,),
        in_specs=[pl.BlockSpec((b, dphi), lambda j: (j, 0)),
                  pl.BlockSpec((b, dphi), lambda j: (j, 0)),
                  pl.BlockSpec((b, dv), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((b, dv), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, dv), phi_q.dtype),
        scratch_shapes=[pltpu.VMEM((dphi, dv), jnp.float32),
                        pltpu.VMEM((1, dphi), jnp.float32)],
        interpret=True,
    )(pq, pk, vp)
    return out[:n]


# ---------------------------------------------------------------------------
# Public multi-kernel wrapper (differentiable)
# ---------------------------------------------------------------------------

def linear_attention_fwd(q, k, v, *, kernels=("elu",), causal: bool = False,
                         block: int = DEFAULT_BLOCK):
    """Sum of per-feature-map Pallas linear-attention terms."""
    one = linear_attention_one_causal_fwd if causal else linear_attention_one_noncausal_fwd
    out = None
    for phi in get_feature_maps(kernels):
        term = one(phi(q), phi(k), v, block=block)
        out = term if out is None else out + term
    return out


def _make_linear(kernels: tuple, causal: bool, block: int):
    @jax.custom_vjp
    def fn(q, k, v):
        return linear_attention_fwd(q, k, v, kernels=kernels, causal=causal,
                                    block=block)

    def fwd(q, k, v):
        return fn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        # O(N) backward via the chunked-scan jnp twin (see jnp_fast.py).
        _, vjp = jax.vjp(
            lambda q_, k_, v_: jnp_fast.linear_attention(
                q_, k_, v_, kernels=kernels, causal=causal), q, k, v)
        return vjp(g)

    fn.defvjp(fwd, bwd)
    return fn


@functools.lru_cache(maxsize=None)
def _cached(kernels: tuple, causal: bool, block: int):
    return _make_linear(kernels, causal, block)


def linear_attention(q, k, v, *, kernels=("elu",), causal: bool = False,
                     block: int = DEFAULT_BLOCK):
    """Differentiable Pallas far-field attention (see module docstring)."""
    return _cached(tuple(kernels), bool(causal), int(block))(q, k, v)
