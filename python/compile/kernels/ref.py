"""Pure-jnp oracles for every attention kernel in the library.

These are the ground truth the Pallas kernels are pinned against (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``) and they double as
the ``--impl jnp`` lowering path for artifacts where interpret-mode Pallas
grid loops dominate CPU runtime (see DESIGN.md §7.5).

All functions operate on a single head: ``q, k, v`` of shape ``(N, d)``
(``v`` may have a different last dim ``dv``). Batching and heads are
``vmap``-ed in at the model layer (L2).

Numerical conventions shared with the Pallas kernels:
  * softmax scores are scaled by ``1/sqrt(d)``;
  * banded masking keeps ``|i - j| <= bandwidth`` (and ``j <= i`` when
    causal);
  * linear-attention denominators are clamped to ``DEN_EPS`` in absolute
    value — phi_3 = tanh is sign-indefinite so the denominator can cross
    zero (paper Sec. 3.2.1 leaves this implicit; we make it explicit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .feature_maps import get_feature_maps

#: Denominator guard for kernelized attention (see module docstring).
DEN_EPS = 1e-6


def _guard_den(den: jax.Array) -> jax.Array:
    """Clamp a denominator away from zero, preserving its sign."""
    return jnp.where(jnp.abs(den) < DEN_EPS, jnp.where(den >= 0, DEN_EPS, -DEN_EPS), den)


# ---------------------------------------------------------------------------
# Full softmax attention (the O(N^2) baseline, paper eq. (1))
# ---------------------------------------------------------------------------

def softmax_attention(q, k, v, *, causal=False):
    """Standard softmax attention, ``softmax(QK^T/sqrt(d)) V``."""
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        mask = jnp.tril(jnp.ones((n, n), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1) @ v


def softmax_attention_weights(q, k, *, causal=False):
    """The attention matrix ``A`` itself (for Fig. 1/3 analysis artifacts)."""
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        mask = jnp.tril(jnp.ones((n, n), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1)


# ---------------------------------------------------------------------------
# Near-field: banded softmax attention (paper eq. (3))
# ---------------------------------------------------------------------------

def band_mask(n: int, bandwidth: int, *, causal: bool = False) -> jax.Array:
    """Boolean ``(n, n)`` mask keeping ``|i-j| <= bandwidth`` (and ``j<=i``)."""
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    m = jnp.abs(i - j) <= bandwidth
    if causal:
        m = m & (j <= i)
    return m


def banded_attention(q, k, v, *, bandwidth: int, causal: bool = False):
    """Near-field attention ``D V`` with ``D = softmax(band_k(QK^T/sqrt(d)))``.

    This oracle materializes the N×N mask — O(N^2) — which is fine for
    correctness testing; the Pallas kernel computes only the band (O(N·k)).
    """
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.where(band_mask(n, bandwidth, causal=causal), scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1) @ v


def banded_attention_weights(q, k, *, bandwidth: int, causal: bool = False):
    """The banded attention matrix ``D`` (for Fig. 8 visualization)."""
    n, d = q.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.where(band_mask(n, bandwidth, causal=causal), scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1)


# ---------------------------------------------------------------------------
# Far-field: multi-kernel linear attention (paper eq. (9))
# ---------------------------------------------------------------------------

def _linear_attention_one_noncausal(phi_q, phi_k, v):
    """One kernelized term: ``phi(Q)(phi(K)^T V) / (phi(Q) sum_j phi(k_j))``."""
    s = phi_k.T @ v                      # (d_phi, dv)  "multipole moments"
    z = phi_k.sum(axis=0)                # (d_phi,)
    num = phi_q @ s                      # (N, dv)
    den = phi_q @ z                      # (N,)
    return num / _guard_den(den)[:, None]


def _linear_attention_one_causal(phi_q, phi_k, v):
    """Causal variant: prefix sums ``S_i = sum_{j<=i} phi(k_j) v_j^T``."""
    # (N, d_phi, dv) outer products, then inclusive prefix sum over N.
    kv = jnp.cumsum(phi_k[:, :, None] * v[:, None, :], axis=0)
    z = jnp.cumsum(phi_k, axis=0)        # (N, d_phi)
    num = jnp.einsum("np,npv->nv", phi_q, kv)
    den = jnp.einsum("np,np->n", phi_q, z)
    return num / _guard_den(den)[:, None]


def linear_attention(q, k, v, *, kernels=("elu",), causal: bool = False):
    """Far-field attention: sum of per-feature-map normalized linear terms.

    ``kernels`` is a list of feature-map names (see ``feature_maps.py``);
    the rank of the induced far-field matrix L is ``len(kernels)`` (paper
    Prop. 1).
    """
    out = None
    for phi in get_feature_maps(kernels):
        pq, pk = phi(q), phi(k)
        term = (_linear_attention_one_causal if causal else _linear_attention_one_noncausal)(pq, pk, v)
        out = term if out is None else out + term
    return out


def linear_attention_weights(q, k, *, kernels=("elu",), causal: bool = False):
    """The (rank-r) far-field matrix ``L`` itself — O(N^2), analysis only."""
    n = q.shape[0]
    total = jnp.zeros((n, n), q.dtype)
    for phi in get_feature_maps(kernels):
        pq, pk = phi(q), phi(k)
        scores = pq @ pk.T               # (N, N)
        if causal:
            scores = jnp.where(jnp.tril(jnp.ones((n, n), bool)), scores, 0.0)
        den = scores.sum(axis=-1)
        total = total + scores / _guard_den(den)[:, None]
    return total


# ---------------------------------------------------------------------------
# Far-field alternative: fast-weight / delta-rule attention (paper App. 10)
# ---------------------------------------------------------------------------

def _sum_normalize(x):
    """Schlag et al.'s sum normalization of feature vectors."""
    s = x.sum(axis=-1, keepdims=True)
    return x / _guard_den(s)


def fastweight_attention(q, k, v, beta, *, kernels=("elu",)):
    """Delta-rule fast-weight attention (causal by construction).

    State update per step t (Schlag et al. [54], with the FMMformer's
    "attention normalization" — we also carry a linear-attention-style
    normalizer z):

        kbar_t = phi(k_t) / sum(phi(k_t))
        vbar_t = S_{t-1} kbar_t
        S_t    = S_{t-1} + beta_t (v_t - vbar_t) kbar_t^T
        z_t    = z_{t-1} + kbar_t
        out_t  = (S_t qbar_t) / (z_t . qbar_t)

    ``beta``: shape ``(N,)``, in (0,1) (the model applies a sigmoid).
    Implemented with ``lax.scan`` so JAX can reverse-differentiate it; the
    Pallas kernel in ``fastweight.py`` is the chunked forward.
    """
    out = None
    for phi in get_feature_maps(kernels):
        qb = _sum_normalize(phi(q))
        kb = _sum_normalize(phi(k))
        dv = v.shape[-1]
        dphi = qb.shape[-1]

        def step(carry, inp):
            s, z = carry
            qb_t, kb_t, v_t, b_t = inp
            vbar = s @ kb_t                       # (dv,)
            s = s + b_t * jnp.outer(v_t - vbar, kb_t)
            z = z + kb_t
            num = s @ qb_t                        # (dv,)
            den = _guard_den(z @ qb_t)
            return (s, z), num / den

        init = (jnp.zeros((dv, dphi), q.dtype), jnp.zeros((dphi,), q.dtype))
        _, term = jax.lax.scan(step, init, (qb, kb, v, beta))
        out = term if out is None else out + term
    return out


# ---------------------------------------------------------------------------
# FMM blend: near-field + far-field (paper eq. (11))
# ---------------------------------------------------------------------------

def fmm_attention(q, k, v, *, bandwidth: int, kernels=("elu",), w1=1.0, w2=1.0,
                  causal: bool = False):
    """``(w1 D + w2 L) V`` — the FMMformer attention.

    ``w1, w2`` are the *already sigmoid-ed* blending weights (the model
    owns the raw parameters and the sigmoid, paper eq. (11)).
    """
    near = banded_attention(q, k, v, bandwidth=bandwidth, causal=causal)
    far = linear_attention(q, k, v, kernels=kernels, causal=causal)
    return w1 * near + w2 * far


def fmm_fastweight_attention(q, k, v, beta, *, bandwidth: int, kernels=("elu",),
                             w1=1.0, w2=1.0):
    """FMM blend with the delta-rule far-field (paper Table 3, causal)."""
    near = banded_attention(q, k, v, bandwidth=bandwidth, causal=True)
    far = fastweight_attention(q, k, v, beta, kernels=kernels)
    return w1 * near + w2 * far
