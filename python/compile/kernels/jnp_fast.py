"""O(N) pure-jnp implementations of the near/far-field attentions.

``ref.py`` keeps the *obviously correct* oracles (dense N×N masks, full
cumsums). Those are fine for pinning kernels at test sizes but are O(N^2)
time or O(N·d^2) memory, which would poison the Fig. 6 scaling study and
the custom_vjp backward passes at long N. This module provides
linear-complexity jnp equivalents:

  * ``banded_attention`` — diagonal-offset formulation: for each offset
    delta in [-k, k], ``score_delta[i] = q_i · k_{i+delta}`` is a shifted
    elementwise product. O(N·k·d) time, O(N·(k+d)) memory; the N×N matrix
    never exists.
  * ``linear_attention`` — non-causal is the two-matmul form; causal is a
    chunked ``lax.scan`` carrying the (S, z) prefix state (the jnp twin of
    the Pallas causal kernel's schedule).
  * ``fastweight_attention`` — re-exported scan reference (already O(N)).

Equality with ``ref.py`` is pinned in ``python/tests/test_kernels.py``;
these functions are the ``--impl jnp`` lowering path and the backward
bases for the Pallas custom_vjps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .feature_maps import get_feature_maps

NEG_INF = -1e30


def _shift_rows(x, delta):
    """Rows shifted so that row i holds x[i+delta] (zeros out of range)."""
    n = x.shape[0]
    if delta == 0:
        return x
    if abs(delta) >= n:
        return jnp.zeros_like(x)
    if delta > 0:
        return jnp.pad(x[delta:], ((0, delta), (0, 0)))
    return jnp.pad(x[:delta], ((-delta, 0), (0, 0)))


def banded_attention(q, k, v, *, bandwidth: int, causal: bool = False):
    """Banded softmax attention via diagonal offsets — O(N·k·d)."""
    n, d = q.shape
    scale = 1.0 / (d ** 0.5)
    offsets = range(-bandwidth, 1 if causal else bandwidth + 1)
    idx = jnp.arange(n)

    cols, valids = [], []
    for delta in offsets:
        ks = _shift_rows(k, delta)
        cols.append(jnp.sum(q * ks, axis=-1) * scale)       # (N,)
        valids.append((idx + delta >= 0) & (idx + delta < n))
    scores = jnp.stack(cols, axis=1)                         # (N, n_off)
    valid = jnp.stack(valids, axis=1)
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=1)                       # rows sum to 1

    out = jnp.zeros((n, v.shape[-1]), v.dtype)
    for col, delta in enumerate(offsets):
        vs = _shift_rows(v, delta)
        out = out + p[:, col:col + 1] * vs
    return out


def _linear_one_causal_chunked(phi_q, phi_k, v, *, chunk: int = 128):
    """Chunked-scan causal linear attention — O(N·d_phi·dv) time, O(chunk^2)
    extra memory. Mirrors the Pallas causal kernel's math exactly."""
    n, dphi = phi_q.shape
    dv = v.shape[-1]
    c = min(chunk, n)
    n_pad = (n + c - 1) // c * c
    pq = jnp.pad(phi_q, ((0, n_pad - n), (0, 0)))
    pk = jnp.pad(phi_k, ((0, n_pad - n), (0, 0)))
    pv = jnp.pad(v, ((0, n_pad - n), (0, 0)))
    nb = n_pad // c

    rows = jnp.arange(c)[:, None]
    colsm = jnp.arange(c)[None, :]
    within_mask = colsm <= rows

    def step(carry, blk):
        s, z = carry                            # (dphi, dv), (dphi,)
        bq, bk, bv = blk
        num = bq @ s                            # cross-block
        den = bq @ z
        a = jnp.where(within_mask, bq @ bk.T, 0.0)
        num = num + a @ bv
        den = den + a.sum(axis=-1)
        s = s + bk.T @ bv
        z = z + bk.sum(axis=0)
        return (s, z), (num, den)

    blocks = (pq.reshape(nb, c, dphi), pk.reshape(nb, c, dphi), pv.reshape(nb, c, dv))
    init = (jnp.zeros((dphi, dv), phi_q.dtype), jnp.zeros((dphi,), phi_q.dtype))
    _, (num, den) = jax.lax.scan(step, init, blocks)
    num = num.reshape(n_pad, dv)[:n]
    den = den.reshape(n_pad)[:n]
    return num / ref._guard_den(den)[:, None]


def linear_attention(q, k, v, *, kernels=("elu",), causal: bool = False,
                     chunk: int = 128):
    """Multi-kernel far-field attention — O(N) in both modes."""
    out = None
    for phi in get_feature_maps(kernels):
        pq, pk = phi(q), phi(k)
        if causal:
            term = _linear_one_causal_chunked(pq, pk, v, chunk=chunk)
        else:
            term = ref._linear_attention_one_noncausal(pq, pk, v)
        out = term if out is None else out + term
    return out


#: The scan reference is already O(N); re-export for impl dispatch symmetry.
fastweight_attention = ref.fastweight_attention


def fmm_attention(q, k, v, *, bandwidth: int, kernels=("elu",), w1=1.0,
                  w2=1.0, causal: bool = False):
    """O(N) FMM blend (near + far), jnp path."""
    near = banded_attention(q, k, v, bandwidth=bandwidth, causal=causal)
    far = linear_attention(q, k, v, kernels=kernels, causal=causal)
    return w1 * near + w2 * far
