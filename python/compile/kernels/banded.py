"""Pallas near-field (banded softmax) attention kernel.

Near-field attention is ``D V`` with ``D = softmax(band_k(QK^T/sqrt(d)))``
(paper eq. (3)). Only the band is ever computed: O(N·k) work and O(N)
memory instead of O(N^2).

TPU mapping (DESIGN.md §6, Hardware-Adaptation):
  * grid over query blocks of ``BQ`` rows — each grid step is one
    HBM→VMEM stream of a query tile;
  * the key/value window for query block ``i`` covers global rows
    ``[(i-1)·B, (i+2)·B)``. We express the overlapping window without
    unblocked indexing by zero-padding K/V with one block on each side
    and passing the *same* padded array through three BlockSpecs whose
    index maps are ``i``, ``i+1``, ``i+2`` — the kernel concatenates the
    three VMEM tiles;
  * the band mask is recomputed from global row/col indices inside the
    kernel — the N×N mask never exists;
  * scores hit the MXU (``q @ k_win^T``), masking + softmax run on the
    VPU.

Constraint: ``bandwidth <= block`` (the window spans one block on each
side). The wrapper picks ``block = max(min_block, bandwidth)`` rounded up
to a multiple of 8, so any bandwidth works.

VMEM footprint per grid step (f32 words):
    BQ·d (q) + 3B·(d + dv) (k,v window) + BQ·3B (scores) + BQ·dv (out)
e.g. B=128, d=dv=64: ~0.45 MiB — far under the 16 MiB VMEM budget, which
leaves room for double buffering (see EXPERIMENTS.md §Perf).

Backward: ``banded_attention`` is wrapped in ``jax.custom_vjp`` — Pallas
forward, reverse via ``jax.vjp`` of the jnp reference with the *banded*
O(N·k) math (never the dense mask oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import jnp_fast

#: Default (and minimum) query/key block size. Multiple of the 8-row f32
#: sublane tile; 128 matches the MXU systolic dimension.
DEFAULT_BLOCK = 128

NEG_INF = -1e30  # used instead of -inf: keeps masked softmax NaN-free


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _banded_kernel(q_ref, k0_ref, k1_ref, k2_ref, v0_ref, v1_ref, v2_ref,
                   o_ref, *, block: int, bandwidth: int, n: int, causal: bool,
                   scale: float):
    """One query block vs its 3-block key/value window."""
    i = pl.program_id(0)
    q = q_ref[...]                                   # (B, d)
    k_win = jnp.concatenate([k0_ref[...], k1_ref[...], k2_ref[...]], axis=0)
    v_win = jnp.concatenate([v0_ref[...], v1_ref[...], v2_ref[...]], axis=0)

    # MXU: (B, d) @ (d, 3B) -> (B, 3B)
    scores = jnp.dot(q, k_win.T, preferred_element_type=jnp.float32) * scale

    # Global indices. Rows: i*B + r. Window cols: (i-1)*B + c for the
    # padded layout (window block 0 is the pad/previous block).
    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    cols = (i - 1) * block + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = (jnp.abs(rows - cols) <= bandwidth) & (cols >= 0) & (cols < n)
    if causal:
        mask = mask & (cols <= rows)

    scores = jnp.where(mask, scores, NEG_INF)
    # Band always contains the diagonal (j = i), so rows are never empty
    # for rows < n; fully-padded rows (rows >= n) softmax over NEG_INF
    # uniformly — harmless garbage that the wrapper slices off.
    p = jax.nn.softmax(scores, axis=-1)
    o_ref[...] = jnp.dot(p, v_win, preferred_element_type=jnp.float32).astype(o_ref.dtype)


def banded_attention_fwd(q, k, v, *, bandwidth: int, causal: bool = False,
                         block: int = DEFAULT_BLOCK):
    """Pallas forward for one head: q,k (N,d), v (N,dv) -> (N,dv)."""
    n, d = q.shape
    dv = v.shape[-1]
    b = _round_up(max(block, bandwidth, 8), 8)
    n_pad = _round_up(n, b)
    grid = n_pad // b

    qp = jnp.pad(q, ((0, n_pad - n), (0, 0)))
    # K/V padded to n_pad, plus one zero block on each side for the window.
    kp = jnp.pad(k, ((b, n_pad - n + b), (0, 0)))
    vp = jnp.pad(v, ((b, n_pad - n + b), (0, 0)))

    kernel = functools.partial(
        _banded_kernel, block=b, bandwidth=bandwidth, n=n, causal=causal,
        scale=1.0 / (d ** 0.5))

    kv_spec = lambda off: pl.BlockSpec((b, d), lambda i, o=off: (i + o, 0))
    vv_spec = lambda off: pl.BlockSpec((b, dv), lambda i, o=off: (i + o, 0))
    out = pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (i, 0)),      # q
            kv_spec(0), kv_spec(1), kv_spec(2),           # k window
            vv_spec(0), vv_spec(1), vv_spec(2),           # v window
        ],
        out_specs=pl.BlockSpec((b, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, dv), q.dtype),
        interpret=True,   # CPU PJRT cannot run Mosaic custom-calls
    )(qp, kp, kp, kp, vp, vp, vp)
    return out[:n]


def _make_banded(bandwidth: int, causal: bool, block: int):
    """Build the custom_vjp-wrapped banded attention for static config."""

    @jax.custom_vjp
    def fn(q, k, v):
        return banded_attention_fwd(q, k, v, bandwidth=bandwidth,
                                    causal=causal, block=block)

    def fwd(q, k, v):
        return fn(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        # Reverse-mode through the O(N·k) diagonal-offset jnp twin (NOT the
        # dense oracle — backward must stay linear in N). Equality of the
        # twin with both the oracle and this Pallas fwd is pytest-pinned.
        _, vjp = jax.vjp(
            lambda q_, k_, v_: jnp_fast.banded_attention(
                q_, k_, v_, bandwidth=bandwidth, causal=causal), q, k, v)
        return vjp(g)

    fn.defvjp(fwd, bwd)
    return fn


@functools.lru_cache(maxsize=None)
def _cached(bandwidth: int, causal: bool, block: int):
    return _make_banded(bandwidth, causal, block)


def banded_attention(q, k, v, *, bandwidth: int, causal: bool = False,
                     block: int = DEFAULT_BLOCK):
    """Differentiable Pallas banded attention (see module docstring)."""
    return _cached(int(bandwidth), bool(causal), int(block))(q, k, v)
