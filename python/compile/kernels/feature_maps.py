"""Feature maps for far-field (low-rank) attention.

The FMMformer models far-field attention with a sum of kernelized
linear-attention terms, one per feature map phi_l (paper Sec. 3.2.1).
The paper uses:

    phi_1(x) = elu(x) + 1        (the linear-transformer map, [29])
    phi_2(x) = elu(-x) + 1
    phi_3(x) = tanh(x)

which are linearly independent for almost all x (paper Prop. 1), so the
induced far-field matrix L has rank r = #maps.

Each map operates elementwise on the last dimension of Q/K.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["FEATURE_MAPS", "get_feature_maps", "elu_plus_one", "neg_elu_plus_one", "tanh_map"]


def elu_plus_one(x: jax.Array) -> jax.Array:
    """phi_1(x) = elu(x) + 1 (strictly positive; the linear-transformer map)."""
    return jax.nn.elu(x) + 1.0


def neg_elu_plus_one(x: jax.Array) -> jax.Array:
    """phi_2(x) = elu(-x) + 1 (mirror of phi_1; strictly positive)."""
    return jax.nn.elu(-x) + 1.0


def tanh_map(x: jax.Array) -> jax.Array:
    """phi_3(x) = tanh(x). Sign-indefinite: callers must guard denominators."""
    return jnp.tanh(x)


#: Registry keyed by the short names used in configs and artifact manifests.
FEATURE_MAPS = {
    "elu": elu_plus_one,
    "elu_neg": neg_elu_plus_one,
    "tanh": tanh_map,
}


def get_feature_maps(names):
    """Resolve a list of feature-map names to callables.

    Raises KeyError with the known names listed on a bad name, so config
    typos fail loudly at trace time rather than producing a wrong model.
    """
    maps = []
    for n in names:
        if n not in FEATURE_MAPS:
            raise KeyError(f"unknown feature map {n!r}; known: {sorted(FEATURE_MAPS)}")
        maps.append(FEATURE_MAPS[n])
    return maps
