"""Pallas fast-weight (delta-rule) attention kernel — paper Appendix 10.

The fast-weight transformer (Schlag et al. [54]) replaces the additive
linear-attention state update with the *delta rule*:

    kbar_t = phi(k_t)/sum(phi(k_t));  vbar_t = S_{t-1} kbar_t
    S_t    = S_{t-1} + beta_t (v_t - vbar_t) kbar_t^T
    out_t  = (S_t qbar_t) / (z_t · qbar_t),   z_t = z_{t-1} + kbar_t

The update is inherently sequential in t (each step reads the state the
previous step wrote), so the TPU schedule is: sequential grid over
sequence chunks carrying (S, z) in VMEM scratch, and a ``fori_loop`` over
the rows *inside* each chunk — the chunk amortizes the HBM→VMEM streaming
while the loop body is pure VPU/MXU register work on resident tiles.

The wrapper applies the feature map + sum normalization (fused by XLA).
Backward: jax.vjp of the scan-based jnp reference (banded.py rationale).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref
from .feature_maps import get_feature_maps

#: Sequence chunk per grid step. Smaller than the matmul kernels' block:
#: the inner loop is sequential, so the chunk only amortizes streaming.
DEFAULT_CHUNK = 64


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fastweight_kernel(qb_ref, kb_ref, v_ref, beta_ref, o_ref, s_ref, z_ref,
                       *, chunk: int, eps: float):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _():
        s_ref[...] = jnp.zeros_like(s_ref)
        z_ref[...] = jnp.zeros_like(z_ref)

    qb = qb_ref[...]        # (C, d_phi) sum-normalized phi(q)
    kb = kb_ref[...]        # (C, d_phi)
    v = v_ref[...]          # (C, dv)
    beta = beta_ref[...]    # (C, 1)

    def body(t, carry):
        s, z, out = carry                     # s: (dv, d_phi), z: (d_phi,)
        kb_t = kb[t, :]
        vbar = s @ kb_t                       # (dv,)
        s = s + beta[t, 0] * jnp.outer(v[t, :] - vbar, kb_t)
        z = z + kb_t
        qb_t = qb[t, :]
        den = z @ qb_t
        den = jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)
        out = out.at[t, :].set((s @ qb_t) / den)
        return s, z, out

    s0 = s_ref[...]
    z0 = z_ref[0, :]
    out0 = jnp.zeros(o_ref.shape, jnp.float32)
    s, z, out = jax.lax.fori_loop(0, chunk, body, (s0, z0, out0))

    o_ref[...] = out.astype(o_ref.dtype)
    s_ref[...] = s.astype(s_ref.dtype)
    z_ref[0, :] = z.astype(z_ref.dtype)


def fastweight_attention_one_fwd(qb, kb, v, beta, *, chunk: int = DEFAULT_CHUNK):
    """One feature map. qb, kb: sum-normalized phi(q/k), (N, d_phi)."""
    n, dphi = qb.shape
    dv = v.shape[-1]
    c = min(_round_up(max(chunk, 8), 8), _round_up(n, 8))
    n_pad = _round_up(n, c)
    grid = n_pad // c

    # Padded rows: beta = 0 => the state update is a no-op there, so the
    # carried state never sees padding. (kb rows may be zero-padded too.)
    qp = jnp.pad(qb, ((0, n_pad - n), (0, 0)))
    kp = jnp.pad(kb, ((0, n_pad - n), (0, 0)))
    vp = jnp.pad(v, ((0, n_pad - n), (0, 0)))
    bp = jnp.pad(beta.reshape(n, 1), ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_fastweight_kernel, chunk=c, eps=ref.DEN_EPS),
        grid=(grid,),
        in_specs=[pl.BlockSpec((c, dphi), lambda j: (j, 0)),
                  pl.BlockSpec((c, dphi), lambda j: (j, 0)),
                  pl.BlockSpec((c, dv), lambda j: (j, 0)),
                  pl.BlockSpec((c, 1), lambda j: (j, 0))],
        out_specs=pl.BlockSpec((c, dv), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, dv), qb.dtype),
        scratch_shapes=[pltpu.VMEM((dv, dphi), jnp.float32),
                        pltpu.VMEM((1, dphi), jnp.float32)],
        interpret=True,
    )(qp, kp, vp, bp)
    return out[:n]


def _sum_normalize(x):
    s = x.sum(axis=-1, keepdims=True)
    eps = ref.DEN_EPS
    s = jnp.where(jnp.abs(s) < eps, jnp.where(s >= 0, eps, -eps), s)
    return x / s


def fastweight_attention_fwd(q, k, v, beta, *, kernels=("elu",),
                             chunk: int = DEFAULT_CHUNK):
    out = None
    for phi in get_feature_maps(kernels):
        term = fastweight_attention_one_fwd(
            _sum_normalize(phi(q)), _sum_normalize(phi(k)), v, beta, chunk=chunk)
        out = term if out is None else out + term
    return out


def _make_fastweight(kernels: tuple, chunk: int):
    @jax.custom_vjp
    def fn(q, k, v, beta):
        return fastweight_attention_fwd(q, k, v, beta, kernels=kernels, chunk=chunk)

    def fwd(q, k, v, beta):
        return fn(q, k, v, beta), (q, k, v, beta)

    def bwd(res, g):
        q, k, v, beta = res
        _, vjp = jax.vjp(
            lambda q_, k_, v_, b_: ref.fastweight_attention(
                q_, k_, v_, b_, kernels=kernels), q, k, v, beta)
        return vjp(g)

    fn.defvjp(fwd, bwd)
    return fn


@functools.lru_cache(maxsize=None)
def _cached(kernels: tuple, chunk: int):
    return _make_fastweight(kernels, chunk)


def fastweight_attention(q, k, v, beta, *, kernels=("elu",),
                         chunk: int = DEFAULT_CHUNK):
    """Differentiable Pallas delta-rule attention (see module docstring)."""
    return _cached(tuple(kernels), int(chunk))(q, k, v, beta)
