"""L1 — Pallas attention kernels and their jnp oracles.

Public dispatch surface used by the L2 model (``compile/model.py``). Every
attention has two implementations selected by ``impl``:

  * ``"pallas"`` — the Pallas kernel (interpret=True on CPU; the TPU
    production path), wrapped in custom_vjp for reverse-mode;
  * ``"jnp"`` — the pure-jnp reference from ``ref.py`` (also the oracle
    the Pallas path is pytest-pinned against).

The selected impl is recorded in each AOT artifact's manifest.
"""
from __future__ import annotations

from . import jnp_fast, ref
from .banded import banded_attention as banded_attention_pallas
from .fastweight import fastweight_attention as fastweight_attention_pallas
from .feature_maps import FEATURE_MAPS, get_feature_maps
from .lowrank import linear_attention as linear_attention_pallas

IMPLS = ("pallas", "jnp")


def _check_impl(impl: str) -> None:
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; known: {IMPLS}")


def banded_attention(q, k, v, *, bandwidth, causal=False, impl="pallas"):
    """Near-field attention D·V (paper eq. 3). O(N·bandwidth)."""
    _check_impl(impl)
    if impl == "pallas":
        return banded_attention_pallas(q, k, v, bandwidth=bandwidth, causal=causal)
    return jnp_fast.banded_attention(q, k, v, bandwidth=bandwidth, causal=causal)


def linear_attention(q, k, v, *, kernels=("elu",), causal=False, impl="pallas"):
    """Far-field attention L·V (paper eq. 9). O(N·r·d)."""
    _check_impl(impl)
    if impl == "pallas":
        return linear_attention_pallas(q, k, v, kernels=kernels, causal=causal)
    return jnp_fast.linear_attention(q, k, v, kernels=kernels, causal=causal)


def fastweight_attention(q, k, v, beta, *, kernels=("elu",), impl="pallas"):
    """Delta-rule far-field attention (paper App. 10). Causal, O(N·d^2)."""
    _check_impl(impl)
    if impl == "pallas":
        return fastweight_attention_pallas(q, k, v, beta, kernels=kernels)
    return ref.fastweight_attention(q, k, v, beta, kernels=kernels)


def softmax_attention(q, k, v, *, causal=False, impl="jnp"):
    """Full O(N^2) softmax attention — the baseline; jnp only (no Pallas
    kernel: the paper's point is to *avoid* this computation)."""
    return ref.softmax_attention(q, k, v, causal=causal)


__all__ = [
    "FEATURE_MAPS", "get_feature_maps", "ref", "IMPLS",
    "banded_attention", "linear_attention", "fastweight_attention",
    "softmax_attention",
]
