"""L2 — analysis artifact builders (Figs. 1, 3, 8 and the Fig. 6 unit).

These lower *inspection* functions to HLO so the Rust side can extract
attention matrices from trained checkpoints (via the same param ABI as the
train artifacts) and run the paper's structural studies:

  * ``make_attn_weights`` — full softmax attention matrices A per
    (layer, head) for a batch of sequences. Feeds the Fig. 3 SVD/rank
    study and the Fig. 1 decomposition illustration (Rust does the SVD).
  * ``make_fmm_maps`` — the near-field D and far-field L matrices of an
    FMM model (Fig. 8 heatmaps).
  * ``make_attn_fwdbwd`` — a single attention forward+backward over
    (q, k, v), the timing unit of the Fig. 6 scaling study.

The N×N outputs are intentional here — the entire point of these
artifacts is to materialize the maps for offline analysis; they are never
on a hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from . import model as M
from .kernels import ref


def _per_layer_qk(cfg: M.ModelConfig, params: dict, tokens):
    """Replay the forward pass, yielding per-layer (q, k, v, x) with shapes
    (H, N, dh). Mirrors model._mha exactly (same LN, same projections)."""
    x = params["embed"][tokens] + params["pos"][: tokens.shape[0]]
    n, h, dh = tokens.shape[0], cfg.n_heads, cfg.d_head
    out = []
    for layer in params["layers"]:
        xin = M._layer_norm(x, layer["ln1_g"], layer["ln1_b"])
        q = (xin @ layer["wq"]).reshape(n, h, dh).transpose(1, 0, 2)
        k = (xin @ layer["wk"]).reshape(n, h, dh).transpose(1, 0, 2)
        out.append((q, k))
        x = x + M._mha(cfg, layer, xin)
        hfc = M._layer_norm(x, layer["ln2_g"], layer["ln2_b"])
        x = x + jax.nn.gelu(hfc @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
    return out


def make_attn_weights(cfg: M.ModelConfig, template: dict):
    """``(*params, tokens) -> A`` with A: (B, L, H, N, N) softmax maps."""
    n_leaves = len(M.param_leaves(template))

    def one_seq(params, tok):
        qks = _per_layer_qk(cfg, params, tok)
        mats = []
        for q, k in qks:  # (H, N, dh)
            mats.append(jax.vmap(
                lambda q_, k_: ref.softmax_attention_weights(q_, k_, causal=cfg.causal)
            )(q, k))
        return jnp.stack(mats)  # (L, H, N, N)

    def fn(*args):
        params = M.unflatten_like(template, list(args[:n_leaves]))
        tokens = args[n_leaves]
        return (jax.vmap(lambda t: one_seq(params, t))(tokens),)

    return fn, n_leaves


def make_fmm_maps(cfg: M.ModelConfig, template: dict):
    """``(*params, tokens) -> (D, L)``, each (B, Lyr, H, N, N) — the
    blended near-field and far-field maps of an FMM model (Fig. 8)."""
    if not cfg.uses_blend:
        raise ValueError("fmm_maps requires an fmm/fmm_fastweight model")
    n_leaves = len(M.param_leaves(template))

    def one_seq(params, tok):
        qks = _per_layer_qk(cfg, params, tok)
        near, far = [], []
        for layer, (q, k) in zip(params["layers"], qks):
            w1 = jax.nn.sigmoid(layer["blend"][0])
            w2 = jax.nn.sigmoid(layer["blend"][1])
            near.append(w1 * jax.vmap(
                lambda q_, k_: ref.banded_attention_weights(
                    q_, k_, bandwidth=cfg.bandwidth, causal=cfg.causal))(q, k))
            far.append(w2 * jax.vmap(
                lambda q_, k_: ref.linear_attention_weights(
                    q_, k_, kernels=cfg.kernels, causal=cfg.causal))(q, k))
        return jnp.stack(near), jnp.stack(far)

    def fn(*args):
        params = M.unflatten_like(template, list(args[:n_leaves]))
        tokens = args[n_leaves]
        return jax.vmap(lambda t: one_seq(params, t))(tokens)

    return fn, n_leaves


def make_attn_fwdbwd(variant: str, *, bandwidth: int = 30, kernels_list=("elu",),
                     causal: bool = False, impl: str = "pallas"):
    """``(q, k, v) -> (out_mean, dq, dk, dv)`` — the Fig. 6 timing unit.

    ``variant``: softmax | linear | band | fmm. Differentiates through the
    Pallas custom_vjps (O(N) backward for the linear-complexity variants).
    """
    def attn(q, k, v):
        if variant == "softmax":
            return kernels.softmax_attention(q, k, v, causal=causal)
        if variant == "band":
            return kernels.banded_attention(
                q, k, v, bandwidth=bandwidth, causal=causal, impl=impl)
        if variant == "linear":
            return kernels.linear_attention(
                q, k, v, kernels=kernels_list, causal=causal, impl=impl)
        if variant == "fmm":
            return (kernels.banded_attention(
                        q, k, v, bandwidth=bandwidth, causal=causal, impl=impl)
                    + kernels.linear_attention(
                        q, k, v, kernels=kernels_list, causal=causal, impl=impl))
        raise ValueError(variant)

    def fn(q, k, v):
        out, grads = jax.value_and_grad(
            lambda q_, k_, v_: attn(q_, k_, v_).mean(), argnums=(0, 1, 2))(q, k, v)
        return (out,) + grads

    return fn
