"""Shared binary format for parameter/checkpoint files (``*.params.bin``).

Written by ``aot.py`` (initial params) and by the Rust trainer
(checkpoints) — both sides implement exactly this layout so checkpoints
round-trip between them:

    magic   b"FMMP"
    version u32 LE (=1)
    n_leaves u32 LE
    per leaf, in manifest order:
        name_len u16 LE, name utf-8
        ndim     u8, dims u32 LE * ndim
        dtype    u8 (0 = f32, 1 = i32)
        data     row-major little-endian

The Rust twin lives in ``rust/src/runtime/checkpoint.rs``.
"""
from __future__ import annotations

import struct

import numpy as np

MAGIC = b"FMMP"
VERSION = 1
DTYPE_F32 = 0
DTYPE_I32 = 1


def write_params(path: str, leaves) -> None:
    """``leaves``: iterable of (name, np/jnp array)."""
    leaves = [(n, np.asarray(a)) for n, a in leaves]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(leaves)))
        for name, arr in leaves:
            if arr.dtype == np.float32:
                code = DTYPE_F32
            elif arr.dtype == np.int32:
                code = DTYPE_I32
            else:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(struct.pack("<B", code))
            f.write(np.ascontiguousarray(arr).tobytes())


def read_params(path: str):
    """Inverse of ``write_params`` -> list of (name, np array)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        version, n = struct.unpack("<II", f.read(8))
        assert version == VERSION, f"unsupported version {version}"
        for _ in range(n):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            (code,) = struct.unpack("<B", f.read(1))
            dt = {DTYPE_F32: np.float32, DTYPE_I32: np.int32}[code]
            count = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(count * 4), dtype=dt).reshape(dims)
            out.append((name, arr))
    return out
