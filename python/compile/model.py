"""L2 — the FMMformer transformer in JAX, calling the L1 kernels.

One model covers every attention variant in the paper's evaluation:

  * ``softmax``        — full O(N^2) baseline (paper eq. (1))
  * ``band``           — banded-only softmax, Band_k baselines
  * ``linear``         — far-field only; rank r = len(kernels) (eq. (9))
  * ``fmm``            — blended near+far field (eq. (11)), *the* FMMformer
  * ``fastweight``     — delta-rule far-field only (App. 10)
  * ``fmm_fastweight`` — banded + delta-rule far field (Table 3)

Architecture (matching the paper's experimental setup, App. 9): token
embedding + learned positional embedding, pre-LN transformer blocks
(MHA → FFN), final LN, then either an LM head (causal) or mean-pool +
classifier head (LRA tasks).

Parameters are a nested dict pytree; ``param_leaves`` defines the stable
flattening order recorded in artifact manifests so the Rust runtime can
address every leaf by name without ever understanding the pytree.

This module is build-time only — it is lowered to HLO text by ``aot.py``
and never imported on the Rust request path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import kernels

ATTENTION_KINDS = ("softmax", "band", "linear", "fmm", "fastweight", "fmm_fastweight")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model hyper-parameters (baked into each AOT artifact)."""

    vocab_size: int
    seq_len: int
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 128
    attention: str = "softmax"
    bandwidth: int = 5
    kernels: Tuple[str, ...] = ("elu",)
    causal: bool = False
    #: None => LM head over vocab; int => mean-pool classifier.
    num_classes: Optional[int] = None
    #: Kernel implementation lowered into the artifact ("pallas"|"jnp").
    impl: str = "pallas"

    def __post_init__(self):
        if self.attention not in ATTENTION_KINDS:
            raise ValueError(f"attention={self.attention!r} not in {ATTENTION_KINDS}")
        if self.d_model % self.n_heads:
            raise ValueError("d_model must divide evenly into heads")
        if self.attention in ("fastweight", "fmm_fastweight") and not self.causal:
            raise ValueError("delta-rule attention is causal by construction")

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def uses_blend(self) -> bool:
        return self.attention in ("fmm", "fmm_fastweight")

    @property
    def uses_beta(self) -> bool:
        return self.attention in ("fastweight", "fmm_fastweight")

    def to_meta(self) -> dict:
        d = dataclasses.asdict(self)
        d["kernels"] = list(self.kernels)
        return d

    @staticmethod
    def from_meta(d: dict) -> "ModelConfig":
        d = dict(d)
        d["kernels"] = tuple(d["kernels"])
        return ModelConfig(**d)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Initialize the parameter pytree (Xavier-uniform linears, N(0, 0.02)
    embeddings — the setup of the paper's reference codebases)."""
    key = jax.random.PRNGKey(seed)

    def xavier(key, shape):
        limit = (6.0 / (shape[0] + shape[-1])) ** 0.5
        return jax.random.uniform(key, shape, jnp.float32, -limit, limit)

    keys = jax.random.split(key, 3 + cfg.n_layers * 8)
    params = {
        "embed": 0.02 * jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)),
        "pos": 0.02 * jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        k = keys[2 + li * 8: 2 + (li + 1) * 8]
        d, dff = cfg.d_model, cfg.d_ff
        layer = {
            "ln1_g": jnp.ones((d,)), "ln1_b": jnp.zeros((d,)),
            "wq": xavier(k[0], (d, d)), "wk": xavier(k[1], (d, d)),
            "wv": xavier(k[2], (d, d)), "wo": xavier(k[3], (d, d)),
            "ln2_g": jnp.ones((d,)), "ln2_b": jnp.zeros((d,)),
            "w1": xavier(k[4], (d, dff)), "b1": jnp.zeros((dff,)),
            "w2": xavier(k[5], (dff, d)), "b2": jnp.zeros((d,)),
        }
        if cfg.uses_blend:
            # Paper App. 9: blending weights initialized to zeros (near
            # field) and ones (far field); sigmoid applied in the forward.
            layer["blend"] = jnp.array([0.0, 1.0])
        if cfg.uses_beta:
            # Delta-rule writing strength: beta = sigmoid(x w_beta + b),
            # one scalar per (position, head).
            layer["w_beta"] = xavier(k[6], (d, cfg.n_heads))
            layer["b_beta"] = jnp.zeros((cfg.n_heads,))
        params["layers"].append(layer)

    params["lnf_g"] = jnp.ones((cfg.d_model,))
    params["lnf_b"] = jnp.zeros((cfg.d_model,))
    out_dim = cfg.vocab_size if cfg.num_classes is None else cfg.num_classes
    params["head_w"] = xavier(keys[-1], (cfg.d_model, out_dim))
    params["head_b"] = jnp.zeros((out_dim,))
    return params


def param_leaves(params: dict):
    """Flatten to ``[(dotted_name, leaf), ...]`` in a stable, documented
    order (the manifest/param-store order the Rust side relies on)."""
    out = []

    def walk(prefix, node):
        if isinstance(node, dict):
            for name in sorted(node):
                walk(f"{prefix}.{name}" if prefix else name, node[name])
        elif isinstance(node, (list, tuple)):
            for i, item in enumerate(node):
                walk(f"{prefix}.{i}", item)
        else:
            out.append((prefix, node))

    walk("", params)
    return out


def unflatten_like(params_template, leaves):
    """Inverse of ``param_leaves`` given the same template structure."""
    leaves = list(leaves)
    idx = [0]

    def walk(node):
        if isinstance(node, dict):
            return {name: walk(node[name]) for name in sorted(node)}
        if isinstance(node, (list, tuple)):
            return [walk(item) for item in node]
        leaf = leaves[idx[0]]
        idx[0] += 1
        return leaf

    rebuilt = walk(params_template)
    assert idx[0] == len(leaves), "leaf count mismatch"
    return rebuilt


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention_head(cfg: ModelConfig, q, k, v, beta, w1, w2):
    """Dispatch one head's attention. q,k,v: (N, d_head); beta: (N,)."""
    a = cfg.attention
    if a == "softmax":
        return kernels.softmax_attention(q, k, v, causal=cfg.causal)
    if a == "band":
        return kernels.banded_attention(
            q, k, v, bandwidth=cfg.bandwidth, causal=cfg.causal, impl=cfg.impl)
    if a == "linear":
        return kernels.linear_attention(
            q, k, v, kernels=cfg.kernels, causal=cfg.causal, impl=cfg.impl)
    if a == "fastweight":
        return kernels.fastweight_attention(
            q, k, v, beta, kernels=cfg.kernels, impl=cfg.impl)
    if a == "fmm":
        near = kernels.banded_attention(
            q, k, v, bandwidth=cfg.bandwidth, causal=cfg.causal, impl=cfg.impl)
        far = kernels.linear_attention(
            q, k, v, kernels=cfg.kernels, causal=cfg.causal, impl=cfg.impl)
        return w1 * near + w2 * far
    if a == "fmm_fastweight":
        near = kernels.banded_attention(
            q, k, v, bandwidth=cfg.bandwidth, causal=True, impl=cfg.impl)
        far = kernels.fastweight_attention(
            q, k, v, beta, kernels=cfg.kernels, impl=cfg.impl)
        return w1 * near + w2 * far
    raise AssertionError(a)


def _mha(cfg: ModelConfig, layer: dict, x):
    """Multi-head attention over one sequence. x: (N, d_model)."""
    n = x.shape[0]
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ layer["wq"]).reshape(n, h, dh).transpose(1, 0, 2)   # (H, N, dh)
    k = (x @ layer["wk"]).reshape(n, h, dh).transpose(1, 0, 2)
    v = (x @ layer["wv"]).reshape(n, h, dh).transpose(1, 0, 2)

    if cfg.uses_beta:
        beta = jax.nn.sigmoid(x @ layer["w_beta"] + layer["b_beta"]).T  # (H, N)
    else:
        beta = jnp.zeros((h, n))

    if cfg.uses_blend:
        w1 = jax.nn.sigmoid(layer["blend"][0])
        w2 = jax.nn.sigmoid(layer["blend"][1])
    else:
        w1 = w2 = 1.0

    head = lambda q_, k_, v_, b_: _attention_head(cfg, q_, k_, v_, b_, w1, w2)
    out = jax.vmap(head)(q, k, v, beta)                          # (H, N, dh)
    out = out.transpose(1, 0, 2).reshape(n, h * dh)
    return out @ layer["wo"]


def forward_hidden(cfg: ModelConfig, params: dict, tokens):
    """Token ids (N,) int32 -> final hidden states (N, d_model). Pre-LN."""
    x = params["embed"][tokens] + params["pos"][: tokens.shape[0]]
    for layer in params["layers"]:
        x = x + _mha(cfg, layer, _layer_norm(x, layer["ln1_g"], layer["ln1_b"]))
        hfc = _layer_norm(x, layer["ln2_g"], layer["ln2_b"])
        x = x + jax.nn.gelu(hfc @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
    return _layer_norm(x, params["lnf_g"], params["lnf_b"])


def forward(cfg: ModelConfig, params: dict, tokens, *, pad_id: int = 0):
    """Batched forward. tokens: (B, N) int32.

    Returns per-position LM logits (B, N, V) when ``num_classes is None``,
    else masked-mean-pooled classifier logits (B, C) (pad positions — id
    ``pad_id`` — are excluded from the pool; the paper uses mean pooling,
    App. 9).
    """
    hidden = jax.vmap(lambda t: forward_hidden(cfg, params, t))(tokens)
    if cfg.num_classes is None:
        return hidden @ params["head_w"] + params["head_b"]
    mask = (tokens != pad_id).astype(hidden.dtype)[:, :, None]   # (B, N, 1)
    denom = jnp.maximum(mask.sum(axis=1), 1.0)
    pooled = (hidden * mask).sum(axis=1) / denom
    return pooled @ params["head_w"] + params["head_b"]


def count_params(params: dict) -> int:
    return sum(int(leaf.size) for _, leaf in param_leaves(params))
