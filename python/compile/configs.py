"""Named artifact registry — one entry per model/experiment the paper runs.

Every AOT artifact (a lowered HLO computation + JSON manifest) is declared
here, grouped by the paper table/figure it serves (DESIGN.md §5):

  core     — tiny artifacts for quickstart, integration tests, CI
  copy     — Figs. 4 & 5 (synthetic sequence duplication)
  lra      — Table 1 (five LRA-proxy classification tasks)
  lm       — Tables 2 & 3, Fig. 7 (synthetic-WikiText language modeling)
  scaling  — Fig. 6 (attention fwd+bwd time/memory vs N)
  analysis — Figs. 1, 3, 8 (attention-map structure studies)
  serve    — batch-size-bucketed predict executables for the server demo

Scale substitutions vs the paper (documented in DESIGN.md §3): sequence
lengths and model widths are reduced to single-CPU-core budgets; variant
*orderings*, not absolute numbers, are the reproduction target.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from .model import ModelConfig
from .train_step import OptConfig


@dataclasses.dataclass(frozen=True)
class ArtifactSpec:
    """Everything needed to lower + manifest one artifact."""

    name: str
    group: str
    #: train_step | eval_step | predict | attn_weights | fmm_maps | attn_fwdbwd
    kind: str
    model: Optional[ModelConfig] = None
    opt: Optional[OptConfig] = None
    batch: int = 16
    #: Task metadata passed through to the Rust data generators.
    task: Optional[dict] = None
    #: For attn_fwdbwd: dict(variant=..., n=..., d=..., bandwidth=..., kernels=[...]).
    fwdbwd: Optional[dict] = None
    seed: int = 0

    @property
    def param_key(self) -> str:
        """Artifacts with equal keys share a parameter ABI (checkpoints
        are interchangeable between them)."""
        assert self.model is not None
        m = self.model
        return (f"{m.attention}-{m.vocab_size}v-{m.seq_len}n-{m.d_model}d-"
                f"{m.n_heads}h-{m.n_layers}l-{m.d_ff}f-b{m.bandwidth}-"
                f"k{','.join(m.kernels)}-c{int(m.causal)}-cls{m.num_classes}")


# ---------------------------------------------------------------------------
# Variant tables (paper Sec. 4)
# ---------------------------------------------------------------------------

def _variant(attention, bandwidth=5, kernels=("elu",)):
    return dict(attention=attention, bandwidth=bandwidth, kernels=kernels)


#: Fig. 4 — blending linear attention with near-field bands.
COPY_FIG4_VARIANTS = {
    "softmax": _variant("softmax"),
    "linear": _variant("linear"),
    "fmm_band10": _variant("fmm", bandwidth=10),
    "fmm_band20": _variant("fmm", bandwidth=20),
    "fmm_band30": _variant("fmm", bandwidth=30),
}

#: Fig. 5 — far-field rank via multiple feature maps.
COPY_FIG5_VARIANTS = {
    "rank2": _variant("linear", kernels=("elu", "elu_neg")),
    "rank3": _variant("linear", kernels=("elu", "elu_neg", "tanh")),
}

COPY_SEQ_LENS = (128, 256, 512)

#: Table 1 — LRA rows.
LRA_VARIANTS = {
    "softmax": _variant("softmax"),
    "linear": _variant("linear"),
    "band5": _variant("band", bandwidth=5),
    "fmm1_band5": _variant("fmm", bandwidth=5, kernels=("elu",)),
    "fmm2_band5": _variant("fmm", bandwidth=5, kernels=("elu", "elu_neg")),
}

#: LRA-proxy task shapes (paper: 2K/4K/4K/1K/1K — scaled to 1-core CPU).
LRA_TASKS = {
    "listops": dict(seq_len=256, vocab_size=20, num_classes=10),
    "text": dict(seq_len=512, vocab_size=260, num_classes=2),
    "retrieval": dict(seq_len=512, vocab_size=260, num_classes=2),
    "image": dict(seq_len=784, vocab_size=258, num_classes=10),
    "pathfinder": dict(seq_len=576, vocab_size=258, num_classes=2),
}

#: Tables 2 & 3 — LM rows (synthetic-WikiText; Table 3 adds fast weights).
LM_VARIANTS = {
    "softmax": _variant("softmax"),
    "linear": _variant("linear"),
    "band5": _variant("band", bandwidth=5),
    "band20": _variant("band", bandwidth=20),
    "fmm1_band5": _variant("fmm", bandwidth=5, kernels=("elu",)),
    "fmm1_band20": _variant("fmm", bandwidth=20, kernels=("elu",)),
    "fmm2_band20": _variant("fmm", bandwidth=20, kernels=("elu", "elu_neg")),
    "fastweight": _variant("fastweight"),
    "fw_fmm1_band20": _variant("fmm_fastweight", bandwidth=20, kernels=("elu",)),
}

LM_TASK = dict(seq_len=128, vocab_size=1024)
LM_ARCH = dict(d_model=64, n_heads=2, n_layers=2, d_ff=256)

#: Fig. 6 — scaling-study variants (non-causal attention fwd+bwd unit).
SCALING_VARIANTS = {
    "softmax": dict(variant="softmax"),
    "linear1": dict(variant="linear", kernels=("elu",)),
    "linear2": dict(variant="linear", kernels=("elu", "elu_neg")),
    "linear3": dict(variant="linear", kernels=("elu", "elu_neg", "tanh")),
    "fmm3_band30": dict(variant="fmm", kernels=("elu", "elu_neg", "tanh"),
                        bandwidth=30),
}
SCALING_NS = tuple(2 ** p for p in range(9, 17))        # 512 .. 65536
#: Full softmax fwd+bwd at N=2^15 needs >4 N^2 f32 buffers ≈ 17 GiB+ — past
#: this testbed's RAM; the bench reports OOM there, which *is* Fig. 6's point.
SCALING_SOFTMAX_MAX_N = 2 ** 13


# ---------------------------------------------------------------------------
# Registry construction
# ---------------------------------------------------------------------------

def _copy_model(n, variant, impl):
    return ModelConfig(vocab_size=13, seq_len=n, d_model=32, n_heads=2,
                       n_layers=2, d_ff=64, causal=True, impl=impl, **variant)


def _lra_model(task, variant, impl):
    t = LRA_TASKS[task]
    return ModelConfig(vocab_size=t["vocab_size"], seq_len=t["seq_len"],
                       d_model=64, n_heads=2, n_layers=2, d_ff=128,
                       causal=False, num_classes=t["num_classes"], impl=impl,
                       **variant)


def _lm_model(variant, impl):
    return ModelConfig(vocab_size=LM_TASK["vocab_size"],
                       seq_len=LM_TASK["seq_len"], causal=True, impl=impl,
                       **LM_ARCH, **variant)


#: Per-group kernel-impl defaults. core/copy keep the Pallas lowering on
#: their (small) hot paths — real Pallas-in-the-loop training. The bigger
#: groups lower the jnp twins: interpret-mode Pallas wraps each grid step
#: in an XLA while-loop that copies the carried buffer on CPU, which (a)
#: explodes XLA compile time for deep models and (b) makes wallclock
#: superlinear in N — a CPU-interpret artifact, not a property of the
#: kernel schedule (DESIGN.md §7.5; the jnp twins implement the identical
#: O(N) block schedules and are pytest-pinned against both Pallas and the
#: dense oracles).
GROUP_IMPL = {
    "core": "pallas",
    "copy": "pallas",
    "lra": "jnp",
    "lm": "jnp",
    "scaling": "jnp",
    "analysis": "jnp",
    "serve": "jnp",
}


def build_registry(impl: str | None = None):
    """All artifact specs, keyed by name. ``impl`` overrides the per-group
    defaults in GROUP_IMPL when given."""
    gimpl = {g: (impl or d) for g, d in GROUP_IMPL.items()}
    specs = []
    opt = OptConfig()

    # --- core -------------------------------------------------------------
    tiny = ModelConfig(vocab_size=13, seq_len=64, d_model=32, n_heads=2,
                       n_layers=1, d_ff=64, attention="fmm", bandwidth=5,
                       kernels=("elu",), causal=True, impl=gimpl["core"])
    task = dict(task="copy", vocab_size=13, pad_id=0, sep_id=11, n_symbols=10)
    specs += [
        ArtifactSpec("core_tiny", "core", "train_step", tiny, opt, 4, task),
        ArtifactSpec("core_tiny_eval", "core", "eval_step", tiny, None, 4, task),
        ArtifactSpec("core_tiny_predict", "core", "predict", tiny, None, 4, task),
    ]

    # --- copy (Figs. 4 & 5) -------------------------------------------------
    copy_variants = {**COPY_FIG4_VARIANTS, **COPY_FIG5_VARIANTS}
    for n in COPY_SEQ_LENS:
        for vname, variant in copy_variants.items():
            m = _copy_model(n, variant, gimpl["copy"])
            task = dict(task="copy", vocab_size=13, pad_id=0, sep_id=11,
                        n_symbols=10)
            specs.append(ArtifactSpec(f"copy{n}_{vname}", "copy", "train_step",
                                      m, opt, 16, task))

    # --- lra (Table 1) -------------------------------------------------------
    for tname in LRA_TASKS:
        for vname, variant in LRA_VARIANTS.items():
            m = _lra_model(tname, variant, gimpl["lra"])
            task = dict(task=f"lra_{tname}", **LRA_TASKS[tname], pad_id=0)
            specs.append(ArtifactSpec(f"lra_{tname}_{vname}", "lra",
                                      "train_step", m, opt, 8, task))
            specs.append(ArtifactSpec(f"lra_{tname}_{vname}_eval", "lra",
                                      "eval_step", m, None, 8, task))

    # --- lm (Tables 2 & 3, Fig. 7) -------------------------------------------
    for vname, variant in LM_VARIANTS.items():
        m = _lm_model(variant, gimpl["lm"])
        task = dict(task="lm_corpus", **LM_TASK, pad_id=0)
        specs.append(ArtifactSpec(f"lm_{vname}", "lm", "train_step", m, opt,
                                  16, task))
        specs.append(ArtifactSpec(f"lm_{vname}_eval", "lm", "eval_step", m,
                                  None, 16, task))

    # --- scaling (Fig. 6) ------------------------------------------------------
    for vname, v in SCALING_VARIANTS.items():
        for n in SCALING_NS:
            if v["variant"] == "softmax" and n > SCALING_SOFTMAX_MAX_N:
                continue
            specs.append(ArtifactSpec(
                f"scale_{vname}_n{n}", "scaling", "attn_fwdbwd",
                fwdbwd=dict(n=n, d=64, impl=gimpl["scaling"], **v)))

    # --- analysis (Figs. 1, 3, 8) ----------------------------------------------
    lm_softmax = _lm_model(LM_VARIANTS["softmax"], gimpl["lm"])
    lm_fmm = _lm_model(LM_VARIANTS["fmm1_band5"], gimpl["lm"])
    task = dict(task="lm_corpus", **LM_TASK, pad_id=0)
    specs += [
        ArtifactSpec("analysis_lm_softmax_attnmaps", "analysis",
                     "attn_weights", lm_softmax, None, 4, task),
        ArtifactSpec("analysis_lm_fmm_maps", "analysis", "fmm_maps", lm_fmm,
                     None, 4, task),
    ]

    # --- serve (batch-bucketed predict; vllm-style fixed-shape executables) ---
    serve_model = _lra_model("text", LRA_VARIANTS["fmm2_band5"], gimpl["lra"])
    task = dict(task="lra_text", **LRA_TASKS["text"], pad_id=0)
    for b in (1, 4, 8):
        specs.append(ArtifactSpec(f"serve_text_fmm2_b{b}", "serve", "predict",
                                  serve_model, None, b, task))

    reg = {s.name: s for s in specs}
    assert len(reg) == len(specs), "duplicate artifact names"
    return reg


GROUPS = ("core", "copy", "lra", "lm", "scaling", "analysis", "serve")
