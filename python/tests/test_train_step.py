"""L2 train/eval/predict step tests: the flat ABI learns and aggregates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train_step as T

jax.config.update("jax_platform_name", "cpu")


def _cfg(**kw):
    base = dict(vocab_size=13, seq_len=32, d_model=16, n_heads=2, n_layers=1,
                d_ff=32, attention="fmm", bandwidth=3, kernels=("elu",),
                causal=True, impl="jnp")
    base.update(kw)
    return M.ModelConfig(**base)


def _run_steps(cfg, steps, batch=4, lr=3e-3, seed=0):
    params = M.init_params(cfg, seed)
    leaves = M.param_leaves(params)
    step, n = T.make_train_step(cfg, T.OptConfig(lr=lr, warmup_steps=5), params)
    jstep = jax.jit(step)
    rng = np.random.default_rng(seed)
    if cfg.num_classes is None:
        toks = jnp.asarray(rng.integers(1, 11, (batch, cfg.seq_len)), jnp.int32)
        tgts = jnp.concatenate([toks[:, 1:], -jnp.ones((batch, 1), jnp.int32)], 1)
    else:
        toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (batch, cfg.seq_len)),
                           jnp.int32)
        tgts = jnp.asarray(rng.integers(0, cfg.num_classes, (batch,)), jnp.int32)
    p = [a for _, a in leaves]
    m = [jnp.zeros_like(a) for a in p]
    v = [jnp.zeros_like(a) for a in p]
    losses = []
    for t in range(1, steps + 1):
        out = jstep(*p, *m, *v, jnp.float32(t), toks, tgts)
        p, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
        losses.append(float(out[-1]))
    return losses, p


@pytest.mark.parametrize("attention", ["linear", "fmm", "band"])
def test_lm_train_memorizes_batch(attention):
    losses, _ = _run_steps(_cfg(attention=attention), steps=25)
    assert losses[-1] < 0.7 * losses[0], losses[::6]
    assert all(np.isfinite(losses))


def test_classifier_train_memorizes_batch():
    cfg = _cfg(num_classes=4, causal=False, attention="fmm")
    losses, _ = _run_steps(cfg, steps=25)
    assert losses[-1] < 0.7 * losses[0], losses[::6]


def test_fastweight_train_is_stable():
    losses, _ = _run_steps(_cfg(attention="fmm_fastweight"), steps=10)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_lm_loss_ignores_masked_targets():
    cfg = _cfg(attention="linear")
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, 11, (2, cfg.seq_len)), jnp.int32)
    tgts = jnp.asarray(rng.integers(1, 11, (2, cfg.seq_len)), jnp.int32)
    full = T.lm_loss(cfg, params, toks, tgts)
    # Masking half the targets changes the denominator, not validity.
    tgts_masked = tgts.at[:, ::2].set(T.IGNORE_ID)
    half = T.lm_loss(cfg, params, toks, tgts_masked)
    assert np.isfinite(float(full)) and np.isfinite(float(half))
    # Fully ignored => zero loss by convention (0/1 guard).
    none = T.lm_loss(cfg, params, toks, jnp.full_like(tgts, T.IGNORE_ID))
    assert float(none) == 0.0


def test_grad_clipping_bounds_update():
    """With a huge lr the global-norm clip keeps params finite."""
    losses, p = _run_steps(_cfg(attention="linear"), steps=5, lr=10.0)
    for leaf in p:
        assert np.isfinite(np.asarray(leaf)).all()


def test_eval_step_lm_aggregates_tokens():
    cfg = _cfg(attention="fmm")
    params = M.init_params(cfg, 0)
    step, n = T.make_eval_step(cfg, params)
    leaves = [a for _, a in M.param_leaves(params)]
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, 11, (4, cfg.seq_len)), jnp.int32)
    tgts = jnp.concatenate([toks[:, 1:], -jnp.ones((4, 1), jnp.int32)], 1)
    nll_sum, count = jax.jit(step)(*leaves, toks, tgts)
    assert float(count) == 4 * (cfg.seq_len - 1)
    # mean nll ~ log(vocab) for an untrained model on uniform tokens
    mean = float(nll_sum) / float(count)
    assert 1.0 < mean < 5.0


def test_eval_step_cls_counts_correct():
    cfg = _cfg(num_classes=3, causal=False, attention="linear")
    params = M.init_params(cfg, 0)
    step, _ = T.make_eval_step(cfg, params)
    leaves = [a for _, a in M.param_leaves(params)]
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (6, cfg.seq_len)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 3, (6,)), jnp.int32)
    loss_sum, correct = jax.jit(step)(*leaves, toks, labels)
    logits = M.forward(cfg, params, toks)
    want = int((np.argmax(np.asarray(logits), -1) == np.asarray(labels)).sum())
    assert int(correct) == want
    assert 0 <= int(correct) <= 6


def test_predict_matches_forward():
    cfg = _cfg(num_classes=3, causal=False, attention="fmm")
    params = M.init_params(cfg, 0)
    fn, _ = T.make_predict(cfg, params)
    leaves = [a for _, a in M.param_leaves(params)]
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, cfg.seq_len)), jnp.int32)
    (logits,) = jax.jit(fn)(*leaves, toks)
    np.testing.assert_allclose(logits, M.forward(cfg, params, toks), atol=1e-5)


def test_adam_zero_grad_is_noop_after_warmup():
    opt = T.OptConfig()
    p = [jnp.ones((3, 3))]
    m = [jnp.zeros((3, 3))]
    v = [jnp.zeros((3, 3))]
    g = [jnp.zeros((3, 3))]
    np_, nm, nv = T.adam_update(opt, p, m, v, g, jnp.float32(5000.0))
    np.testing.assert_allclose(np_[0], p[0], atol=1e-6)
