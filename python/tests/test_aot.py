"""AOT pipeline tests: manifests are complete, HLO parses, binfmt
round-trips, and the registry covers every paper table/figure group."""
import json
import os
import tempfile

import numpy as np
import pytest

from compile import binfmt
from compile.aot import build_artifact, emit
from compile.configs import GROUPS, build_registry


@pytest.fixture(scope="module")
def registry():
    return build_registry(impl="jnp")   # jnp: fast to trace in tests


def test_registry_covers_all_groups(registry):
    groups = {s.group for s in registry.values()}
    assert groups == set(GROUPS)


def test_registry_has_every_table_row(registry):
    # Table 1: 5 tasks x 5 variants, train + eval.
    for task in ("listops", "text", "retrieval", "image", "pathfinder"):
        for v in ("softmax", "linear", "band5", "fmm1_band5", "fmm2_band5"):
            assert f"lra_{task}_{v}" in registry
            assert f"lra_{task}_{v}_eval" in registry
    # Tables 2 & 3 rows.
    for v in ("softmax", "linear", "band5", "band20", "fmm1_band5",
              "fmm1_band20", "fmm2_band20", "fastweight", "fw_fmm1_band20"):
        assert f"lm_{v}" in registry
    # Figs. 4 & 5 rows at every length.
    for n in (128, 256, 512):
        for v in ("softmax", "linear", "fmm_band10", "fmm_band20",
                  "fmm_band30", "rank2", "rank3"):
            assert f"copy{n}_{v}" in registry


def test_scaling_group_softmax_capped(registry):
    ns = sorted(int(s.fwdbwd["n"]) for s in registry.values()
                if s.group == "scaling" and s.fwdbwd["variant"] == "softmax")
    assert max(ns) <= 2 ** 13
    ns_lin = sorted(int(s.fwdbwd["n"]) for s in registry.values()
                    if s.name.startswith("scale_linear1_"))
    assert max(ns_lin) == 2 ** 16


def test_build_tiny_train_artifact(registry):
    hlo, manifest, init_leaves = build_artifact(registry["core_tiny"])
    assert hlo.startswith("HloModule")
    p = len(manifest["params"])
    assert len(manifest["inputs"]) == 3 * p + 3
    assert len(manifest["outputs"]) == 3 * p + 1
    assert manifest["outputs"][-1]["role"] == "loss"
    assert [e["name"] for e in manifest["params"]] == [n for n, _ in init_leaves]
    roles = {e["role"] for e in manifest["inputs"]}
    assert roles == {"param", "opt_m", "opt_v", "step", "tokens", "targets"}


def test_build_eval_and_predict_artifacts(registry):
    for name, out_roles in [("core_tiny_eval", {"metric"}),
                            ("core_tiny_predict", {"logits"})]:
        hlo, manifest, init = build_artifact(registry[name])
        assert hlo.startswith("HloModule")
        assert init is None
        assert {e["role"] for e in manifest["outputs"]} == out_roles


def test_build_fwdbwd_artifact(registry):
    spec = registry["scale_linear2_n512"]
    hlo, manifest, _ = build_artifact(spec)
    assert hlo.startswith("HloModule")
    assert manifest["outputs"][0]["name"] == "out_mean"
    assert manifest["inputs"][0]["shape"] == [512, 64]


def test_emit_is_idempotent(registry):
    spec = registry["core_tiny_predict"]
    with tempfile.TemporaryDirectory() as d:
        first = emit(spec, d, force=False)
        assert first != "skip"
        assert emit(spec, d, force=False) == "skip"
        man = json.load(open(os.path.join(d, f"{spec.name}.json")))
        assert man["name"] == spec.name


def test_binfmt_roundtrip():
    leaves = [("a.w", np.arange(12, dtype=np.float32).reshape(3, 4)),
              ("b", np.asarray(2.5, dtype=np.float32)),
              ("c.ids", np.asarray([1, -7, 3], dtype=np.int32))]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "p.bin")
        binfmt.write_params(path, leaves)
        back = binfmt.read_params(path)
    assert [n for n, _ in back] == ["a.w", "b", "c.ids"]
    for (_, a), (_, b) in zip(leaves, back):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == b.dtype


def test_param_key_shared_between_train_and_eval(registry):
    t = registry["lm_fmm1_band5"]
    e = registry["lm_fmm1_band5_eval"]
    assert t.param_key == e.param_key
    a = registry["analysis_lm_fmm_maps"]
    assert a.param_key == t.param_key  # Fig. 8 loads the trained checkpoint
