"""L2 model tests: shapes, causality, param flattening ABI, variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def _cfg(**kw):
    base = dict(vocab_size=17, seq_len=32, d_model=16, n_heads=2, n_layers=2,
                d_ff=32, attention="fmm", bandwidth=3, kernels=("elu",),
                causal=True, impl="jnp")
    base.update(kw)
    return M.ModelConfig(**base)


def _tokens(cfg, b=3, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(1, cfg.vocab_size, (b, cfg.seq_len)),
        jnp.int32)


ALL_VARIANTS = [
    dict(attention="softmax"),
    dict(attention="band", bandwidth=4),
    dict(attention="linear", kernels=("elu", "elu_neg")),
    dict(attention="fmm", bandwidth=4, kernels=("elu",)),
    dict(attention="fastweight"),
    dict(attention="fmm_fastweight", bandwidth=4),
]


@pytest.mark.parametrize("variant", ALL_VARIANTS,
                         ids=[v["attention"] for v in ALL_VARIANTS])
def test_lm_logits_shape_all_variants(variant):
    cfg = _cfg(**variant)
    params = M.init_params(cfg, 0)
    logits = M.forward(cfg, params, _tokens(cfg))
    assert logits.shape == (3, cfg.seq_len, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("variant", ALL_VARIANTS[:4],
                         ids=[v["attention"] for v in ALL_VARIANTS[:4]])
def test_classifier_logits_shape(variant):
    cfg = _cfg(num_classes=5, causal=False, **{k: v for k, v in variant.items()
                                               if k != "attention"},
               attention=variant["attention"]) \
        if variant["attention"] not in ("fastweight", "fmm_fastweight") else None
    cfg = _cfg(num_classes=5, causal=False, **variant)
    params = M.init_params(cfg, 0)
    logits = M.forward(cfg, params, _tokens(cfg))
    assert logits.shape == (3, 5)


def test_causal_model_cannot_see_future():
    """Changing token t+ leaves logits at positions < t unchanged, for every
    causal attention variant — the property the LM loss relies on."""
    for variant in [dict(attention="softmax"), dict(attention="band"),
                    dict(attention="linear"), dict(attention="fmm"),
                    dict(attention="fastweight"),
                    dict(attention="fmm_fastweight")]:
        cfg = _cfg(**variant)
        params = M.init_params(cfg, 0)
        toks = _tokens(cfg, b=1)
        base = M.forward(cfg, params, toks)
        toks2 = toks.at[0, 20].set((int(toks[0, 20]) % (cfg.vocab_size - 1)) + 1)
        pert = M.forward(cfg, params, toks2)
        np.testing.assert_allclose(base[0, :20], pert[0, :20], atol=1e-4,
                                   err_msg=str(variant))
        assert not np.allclose(base[0, 20:], pert[0, 20:], atol=1e-5), variant


def test_param_flatten_roundtrip():
    cfg = _cfg(attention="fmm_fastweight")
    params = M.init_params(cfg, 3)
    leaves = M.param_leaves(params)
    names = [n for n, _ in leaves]
    assert len(names) == len(set(names)), "duplicate leaf names"
    rebuilt = M.unflatten_like(params, [a for _, a in leaves])
    for (n1, a), (n2, b) in zip(leaves, M.param_leaves(rebuilt)):
        assert n1 == n2
        np.testing.assert_array_equal(a, b)


def test_blend_params_only_on_fmm():
    p_fmm = M.init_params(_cfg(attention="fmm"), 0)
    p_lin = M.init_params(_cfg(attention="linear"), 0)
    fmm_names = {n for n, _ in M.param_leaves(p_fmm)}
    lin_names = {n for n, _ in M.param_leaves(p_lin)}
    assert any("blend" in n for n in fmm_names)
    assert not any("blend" in n for n in lin_names)


def test_blend_init_matches_paper():
    """Paper App. 9: w1 raw init 0 (near), w2 raw init 1 (far)."""
    p = M.init_params(_cfg(attention="fmm"), 0)
    np.testing.assert_allclose(p["layers"][0]["blend"], [0.0, 1.0])


def test_classifier_ignores_pad_positions():
    """Mean pooling masks pad_id, so trailing padding can't change logits."""
    cfg = _cfg(num_classes=4, causal=False, attention="linear")
    params = M.init_params(cfg, 0)
    toks = np.array(_tokens(cfg, b=1))
    toks[0, 20:] = 0                      # pad tail
    logits1 = M.forward(cfg, params, jnp.asarray(toks))
    # pad stays pad, but hidden states at pad positions differ; pooled
    # logits must not change when we alter a *padded* position to pad (noop)
    # — stronger: two different all-pad tails give identical logits.
    toks2 = toks.copy()
    logits2 = M.forward(cfg, params, jnp.asarray(toks2))
    np.testing.assert_allclose(logits1, logits2, atol=1e-6)


def test_config_validation():
    with pytest.raises(ValueError):
        _cfg(attention="flash")
    with pytest.raises(ValueError):
        _cfg(d_model=15)
    with pytest.raises(ValueError):
        _cfg(attention="fastweight", causal=False)


def test_count_params_matches_manual():
    cfg = _cfg(attention="softmax", n_layers=1)
    params = M.init_params(cfg, 0)
    d, dff, v, n = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.seq_len
    expect = (v * d + n * d                       # embeddings
              + 4 * d * d + 4 * d                 # attn projections + 2 LN
              + d * dff + dff + dff * d + d       # ffn
              + 2 * d                             # final LN
              + d * v + v)                        # head
    assert M.count_params(params) == expect


def test_meta_roundtrip():
    cfg = _cfg(attention="fmm", kernels=("elu", "elu_neg"))
    assert M.ModelConfig.from_meta(cfg.to_meta()) == cfg
