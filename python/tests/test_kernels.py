"""L1 correctness: Pallas kernels and O(N) jnp twins vs the dense oracles.

This is the CORE correctness signal of the build path: everything the Rust
runtime executes was lowered from these kernels, and everything here is
pinned against the obviously-correct dense references in ``ref.py``.

Hypothesis sweeps shapes/bandwidths/ranks/causality. Tolerances: 1e-4
absolute for the positive-definite feature maps; tanh-including *causal*
cases get a denominator-aware bound (den ~ 0.1 amplifies f32 accumulation
order, see kernels/jnp_fast.py discussion in DESIGN.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import jnp_fast, ref
from compile.kernels.feature_maps import FEATURE_MAPS

jax.config.update("jax_platform_name", "cpu")

ATOL = 1e-4


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _tols(kernels):
    """(atol, rtol) per kernel set. tanh denominators can approach zero in
    either causal mode, inflating outputs by ~1/|den| — accumulation-order
    noise then shows up as large *absolute* but small *relative* error, so
    tanh cases get a relative-dominated tolerance (DESIGN.md §7.5)."""
    if "tanh" in kernels:
        return 1e-1, 2e-2
    return ATOL, 2e-3


# ---------------------------------------------------------------------------
# Banded (near-field)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 300), d=st.sampled_from([4, 8, 16, 32]),
       bw=st.integers(0, 40), causal=st.booleans(), seed=st.integers(0, 5))
def test_banded_pallas_vs_ref(n, d, bw, causal, seed):
    q, k, v = (_rand(seed + i, n, d) for i in range(3))
    got = K.banded_attention(q, k, v, bandwidth=bw, causal=causal, impl="pallas")
    want = ref.banded_attention(q, k, v, bandwidth=bw, causal=causal)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 300), d=st.sampled_from([4, 8, 16]),
       bw=st.integers(0, 40), causal=st.booleans(), seed=st.integers(0, 5))
def test_banded_jnpfast_vs_ref(n, d, bw, causal, seed):
    q, k, v = (_rand(seed + i, n, d) for i in range(3))
    got = jnp_fast.banded_attention(q, k, v, bandwidth=bw, causal=causal)
    want = ref.banded_attention(q, k, v, bandwidth=bw, causal=causal)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_banded_rows_sum_to_one():
    """D is row-stochastic: attention over constant V returns V."""
    q, k = _rand(0, 130, 8), _rand(1, 130, 8)
    v = jnp.ones((130, 4))
    out = K.banded_attention(q, k, v, bandwidth=7, causal=True)
    np.testing.assert_allclose(out, 1.0, atol=1e-5)


def test_banded_bandwidth_zero_is_identityish():
    """bandwidth=0 keeps only the diagonal => output == V exactly."""
    q, k, v = _rand(0, 64, 8), _rand(1, 64, 8), _rand(2, 64, 8)
    out = K.banded_attention(q, k, v, bandwidth=0, causal=False)
    np.testing.assert_allclose(out, v, atol=1e-5)


def test_banded_large_bandwidth_equals_full_softmax():
    """bandwidth >= N-1 (non-causal) degenerates to full attention."""
    q, k, v = _rand(0, 96, 16), _rand(1, 96, 16), _rand(2, 96, 16)
    got = K.banded_attention(q, k, v, bandwidth=95, causal=False)
    want = ref.softmax_attention(q, k, v)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_banded_causality():
    """Perturbing a future key/value never changes past outputs."""
    q, k, v = _rand(0, 64, 8), _rand(1, 64, 8), _rand(2, 64, 8)
    base = K.banded_attention(q, k, v, bandwidth=5, causal=True)
    k2 = k.at[40].add(100.0)
    v2 = v.at[40].add(-50.0)
    pert = K.banded_attention(q, k2, v2, bandwidth=5, causal=True)
    np.testing.assert_allclose(base[:40], pert[:40], atol=1e-5)
    assert not np.allclose(base[40:46], pert[40:46], atol=1e-3)


def test_banded_grad_matches_ref_grad():
    q, k, v = _rand(0, 100, 8), _rand(1, 100, 8), _rand(2, 100, 8)
    f = lambda impl: jax.grad(
        lambda q_: (K.banded_attention(q_, k, v, bandwidth=9, causal=True,
                                       impl=impl) ** 2).sum())(q)
    g_ref = jax.grad(
        lambda q_: (ref.banded_attention(q_, k, v, bandwidth=9, causal=True) ** 2).sum())(q)
    np.testing.assert_allclose(f("pallas"), g_ref, atol=1e-3)
    np.testing.assert_allclose(f("jnp"), g_ref, atol=1e-3)


# ---------------------------------------------------------------------------
# Linear (far-field)
# ---------------------------------------------------------------------------

KERNEL_SETS = [("elu",), ("elu_neg",), ("tanh",), ("elu", "elu_neg"),
               ("elu", "elu_neg", "tanh")]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 300), d=st.sampled_from([4, 8, 16, 32]),
       ks=st.sampled_from(KERNEL_SETS), causal=st.booleans(),
       seed=st.integers(0, 5))
def test_linear_pallas_vs_ref(n, d, ks, causal, seed):
    q, k, v = (_rand(seed + i, n, d) for i in range(3))
    got = K.linear_attention(q, k, v, kernels=ks, causal=causal, impl="pallas")
    want = ref.linear_attention(q, k, v, kernels=ks, causal=causal)
    atol, rtol = _tols(ks)
    np.testing.assert_allclose(got, want, atol=atol, rtol=rtol)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 300), d=st.sampled_from([4, 8, 16]),
       ks=st.sampled_from(KERNEL_SETS), causal=st.booleans(),
       seed=st.integers(0, 5))
def test_linear_jnpfast_vs_ref(n, d, ks, causal, seed):
    q, k, v = (_rand(seed + i, n, d) for i in range(3))
    got = jnp_fast.linear_attention(q, k, v, kernels=ks, causal=causal, chunk=64)
    want = ref.linear_attention(q, k, v, kernels=ks, causal=causal)
    atol, rtol = _tols(ks)
    np.testing.assert_allclose(got, want, atol=atol, rtol=rtol)


def test_linear_rank_bound():
    """The far-field matrix L is low-rank *independent of N*: each
    kernelized term is diag(1/den) @ phi(Q) phi(K)^T, rank <= d_phi, so
    rank(L) <= r * d_phi << N (the practical form of paper Prop. 1)."""
    d = 16
    q, k = _rand(0, 80, d), _rand(1, 80, d)
    for ks in KERNEL_SETS[:1] + KERNEL_SETS[3:]:
        L = np.asarray(ref.linear_attention_weights(q, k, kernels=ks))
        s = np.linalg.svd(L, compute_uv=False)
        rank = int((s > 1e-5 * s[0]).sum())
        assert rank <= len(ks) * d, (ks, rank)
        assert rank < 80  # strictly below full rank: it IS a low-rank term


def test_linear_rows_sum_to_r():
    """Each kernelized term is row-normalized: L @ ones == r * ones."""
    q, k = _rand(0, 64, 8), _rand(1, 64, 8)
    for ks in [("elu",), ("elu", "elu_neg")]:
        L = ref.linear_attention_weights(q, k, kernels=ks)
        np.testing.assert_allclose(np.asarray(L).sum(-1), len(ks), atol=1e-4)


def test_linear_causality():
    q, k, v = _rand(0, 64, 8), _rand(1, 64, 8), _rand(2, 64, 8)
    base = K.linear_attention(q, k, v, kernels=("elu",), causal=True)
    pert = K.linear_attention(q, k.at[40].add(10.0), v.at[40].add(10.0),
                              kernels=("elu",), causal=True)
    np.testing.assert_allclose(base[:40], pert[:40], atol=1e-5)


def test_linear_grad_matches_ref_grad():
    q, k, v = _rand(0, 100, 8), _rand(1, 100, 8), _rand(2, 100, 8)
    loss = lambda fn: lambda v_: (fn(q, k, v_) ** 2).sum()
    g_ref = jax.grad(loss(lambda *a: ref.linear_attention(*a, kernels=("elu",), causal=True)))(v)
    g_pal = jax.grad(loss(lambda *a: K.linear_attention(*a, kernels=("elu",), causal=True, impl="pallas")))(v)
    np.testing.assert_allclose(g_pal, g_ref, atol=1e-3)


# ---------------------------------------------------------------------------
# Fast-weight (delta rule)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 200), d=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 5))
def test_fastweight_pallas_vs_ref(n, d, seed):
    q, k, v = (_rand(seed + i, n, d) for i in range(3))
    beta = jax.nn.sigmoid(_rand(seed + 3, n))
    got = K.fastweight_attention(q, k, v, beta, impl="pallas")
    want = ref.fastweight_attention(q, k, v, beta)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_fastweight_beta_zero_equals_empty_state():
    """beta=0 => S stays 0 => output is exactly 0."""
    q, k, v = _rand(0, 50, 8), _rand(1, 50, 8), _rand(2, 50, 8)
    out = ref.fastweight_attention(q, k, v, jnp.zeros(50))
    np.testing.assert_allclose(out, 0.0, atol=1e-6)


def test_fastweight_causality():
    q, k, v = _rand(0, 64, 8), _rand(1, 64, 8), _rand(2, 64, 8)
    beta = jax.nn.sigmoid(_rand(3, 64))
    base = K.fastweight_attention(q, k, v, beta)
    pert = K.fastweight_attention(q, k.at[40].add(5.0), v, beta)
    np.testing.assert_allclose(base[:40], pert[:40], atol=1e-5)


def test_fastweight_grad_finite():
    q, k, v = _rand(0, 48, 8), _rand(1, 48, 8), _rand(2, 48, 8)
    beta = jax.nn.sigmoid(_rand(3, 48))
    g = jax.grad(lambda q_: K.fastweight_attention(q_, k, v, beta).sum())(q)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# FMM blend + feature maps
# ---------------------------------------------------------------------------

def test_fmm_blend_is_weighted_sum():
    q, k, v = _rand(0, 90, 8), _rand(1, 90, 8), _rand(2, 90, 8)
    near = ref.banded_attention(q, k, v, bandwidth=5)
    far = ref.linear_attention(q, k, v, kernels=("elu",))
    blend = ref.fmm_attention(q, k, v, bandwidth=5, kernels=("elu",),
                              w1=0.3, w2=0.7)
    np.testing.assert_allclose(blend, 0.3 * near + 0.7 * far, atol=1e-5)


def test_feature_maps_positive_and_independent():
    x = _rand(0, 64, 16)
    assert (np.asarray(FEATURE_MAPS["elu"](x)) > 0).all()
    assert (np.asarray(FEATURE_MAPS["elu_neg"](x)) > 0).all()
    # Linear independence at a random point: stack as columns, full rank.
    cols = np.stack([np.asarray(FEATURE_MAPS[n](x)).ravel()
                     for n in ("elu", "elu_neg", "tanh")], axis=1)
    assert np.linalg.matrix_rank(cols) == 3


def test_unknown_feature_map_raises():
    with pytest.raises(KeyError):
        K.linear_attention(_rand(0, 8, 4), _rand(1, 8, 4), _rand(2, 8, 4),
                           kernels=("nope",))


def test_unknown_impl_raises():
    with pytest.raises(ValueError):
        K.banded_attention(_rand(0, 8, 4), _rand(1, 8, 4), _rand(2, 8, 4),
                           bandwidth=2, impl="cuda")
