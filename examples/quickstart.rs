//! Quickstart: the whole three-layer stack in ~60 lines.
//!
//! Loads an AOT-compiled FMMformer train-step artifact (JAX+Pallas,
//! lowered by `make artifacts`), trains it on the synthetic copy task for
//! a few dozen steps from Rust via PJRT, evaluates, and saves a
//! checkpoint. Python is never executed here.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use fmmformer::bench::ascii_curve;
use fmmformer::data::{copy_task::CopyTask, Split};
use fmmformer::runtime::Runtime;
use fmmformer::train::Trainer;

fn main() -> Result<()> {
    // 1. A PJRT CPU runtime rooted at the artifacts directory.
    let rt = Runtime::new(&fmmformer::artifacts_dir(None))?;

    // 2. Load + compile the FMMformer train-step executable and its
    //    seeded initial parameters (attention = band5 + elu far field).
    let mut trainer = Trainer::new(&rt, "core_tiny")?;
    println!(
        "model: {} parameters, batch {}, seq len {}",
        trainer.n_params(),
        trainer.art.manifest.batch,
        trainer.art.manifest.seq_len()?
    );

    // 3. Data comes from the Rust side: the paper's sequence-copy task.
    let mut gen = CopyTask::new(trainer.art.manifest.seq_len()?, 0);

    // 4. Train. Each step is ONE device execution: fwd + bwd (through the
    //    Pallas kernels' custom VJPs) + Adam, all in-graph.
    let curve = trainer.train_loop(&mut gen, 120, 40, None)?;
    print!("{}", ascii_curve("copy-task loss", &curve.downsample(60), 60));

    // 5. Evaluate on the held-out split.
    let eval = rt.load("core_tiny_eval")?;
    let result = trainer.evaluate(&eval, &mut gen, Split::Test, 8)?;
    println!(
        "test: nll {:.4} (ppl {:.2}) over {} batches",
        result.loss, result.metric, result.batches
    );

    // 6. Checkpoint (binary format shared with the Python side).
    std::fs::create_dir_all("runs").ok();
    trainer.save_checkpoint(std::path::Path::new("runs/quickstart.ckpt.bin"))?;
    println!("checkpoint -> runs/quickstart.ckpt.bin");
    Ok(())
}
