//! The paper's motivating micro-benchmark (Sec. 4.1): train FMMformer
//! variants on sequence duplication and watch near-field bands rescue
//! linear attention.
//!
//!     make artifacts-copy && cargo run --release --example train_copy -- \
//!         --len 128 --steps 150 --variants linear,fmm_band30

use anyhow::Result;
use fmmformer::bench::ascii_curve;
use fmmformer::cli::Args;
use fmmformer::coordinator::Coordinator;

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let len = args.usize_or("len", 128)?;
    let steps = args.usize_or("steps", 150)?;
    let variants = args.list_or("variants", &["linear", "fmm_band30", "softmax"]);
    let coord = Coordinator::new(&fmmformer::artifacts_dir(args.get("artifacts")),
                                 args.u64_or("seed", 0)?)?;

    println!("copy task, length {len}, {steps} steps per variant\n");
    let mut results = vec![];
    for v in &variants {
        let name = format!("copy{len}_{v}");
        if !coord.rt.has_artifact(&name) {
            println!("{name}: missing (run `make artifacts-copy`)");
            continue;
        }
        let out = coord.run_pipeline(&name, steps, 0, steps / 3)?;
        print!("{}", ascii_curve(&name, &out.curve.downsample(60), 60));
        results.push((v.clone(), out.curve.tail_mean(10)));
    }

    println!("\nfinal loss (tail-10 mean):");
    results.sort_by(|a, b| a.1.total_cmp(&b.1));
    for (v, l) in &results {
        println!("  {v:<14} {l:.4}");
    }
    println!("\nexpected (paper Fig. 4): softmax fastest; adding bands to \
              linear attention closes most of the gap.");
    Ok(())
}
