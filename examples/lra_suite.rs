//! LRA-proxy suite runner: one command to train + evaluate any subset of
//! (task, variant) pairs from Table 1 and print a mini-leaderboard.
//!
//!     make artifacts-lra && cargo run --release --example lra_suite -- \
//!         --tasks listops,image --variants linear,fmm2_band5 --steps 80

use anyhow::Result;
use fmmformer::bench::Table;
use fmmformer::cli::Args;
use fmmformer::coordinator::Coordinator;

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let steps = args.usize_or("steps", 80)?;
    let eval_batches = args.usize_or("eval-batches", 8)?;
    let tasks = args.list_or("tasks", &["listops", "image"]);
    let variants = args.list_or("variants", &["linear", "fmm2_band5"]);
    let coord = Coordinator::new(&fmmformer::artifacts_dir(args.get("artifacts")),
                                 args.u64_or("seed", 0)?)?;

    let mut tbl = Table::new(
        &format!("LRA proxies, {steps} steps per run"),
        &["task", "variant", "test acc %", "valid acc %", "steps/s"],
    );
    for t in &tasks {
        for v in &variants {
            let name = format!("lra_{t}_{v}");
            if !coord.rt.has_artifact(&name) {
                println!("{name}: missing (run `make artifacts-lra`)");
                continue;
            }
            println!("running {name}...");
            let out = coord.run_pipeline(&name, steps, eval_batches, 0)?;
            tbl.row(vec![
                t.clone(),
                v.clone(),
                format!("{:.1}", out.eval_test.map(|e| e.metric * 100.0).unwrap_or(f64::NAN)),
                format!("{:.1}", out.eval_valid.map(|e| e.metric * 100.0).unwrap_or(f64::NAN)),
                format!("{:.2}", steps as f64 / out.train_secs),
            ]);
        }
    }
    tbl.print();
    println!("full Table 1: cargo bench --bench table1_lra");
    Ok(())
}
