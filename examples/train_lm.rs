//! End-to-end driver (the repository's flagship validation run): train an
//! FMMformer language model on the synthetic-WikiText corpus for a few
//! hundred steps, logging train loss and validation perplexity, and
//! compare against the plain linear-transformer baseline — the paper's
//! central claim (FMM > linear) on a real, if small, workload.
//!
//! All layers compose here: L1 attention kernels inside the L2 jax train
//! step, AOT-compiled, driven by the L3 Rust trainer over PJRT with
//! Rust-generated data. Recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts-lm && cargo run --release --example train_lm -- --steps 300

use anyhow::Result;
use fmmformer::bench::ascii_curve;
use fmmformer::cli::Args;
use fmmformer::coordinator::Coordinator;
use fmmformer::data::Split;
use fmmformer::train::{CsvLogger, Trainer};

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let steps = args.usize_or("steps", 300)?;
    let eval_every = args.usize_or("eval-every", 50)?;
    let variants = args.list_or("variants", &["lm_fmm1_band20", "lm_linear"]);
    let coord = Coordinator::new(&fmmformer::artifacts_dir(args.get("artifacts")),
                                 args.u64_or("seed", 0)?)?;
    std::fs::create_dir_all(&coord.runs_dir).ok();

    let mut finals = vec![];
    for name in &variants {
        println!("=== {name} ===");
        let mut trainer = Trainer::new(&coord.rt, name)?;
        let mut gen = coord.generator(name)?;
        let eval_art = coord.rt.load(&format!("{name}_eval"))?;
        println!("{} parameters", trainer.n_params());

        let mut log = CsvLogger::create(
            &coord.runs_dir.join(format!("{name}.e2e.csv")),
            &["step", "train_loss", "valid_ppl"],
        )?;
        let t0 = std::time::Instant::now();
        let mut full_curve = fmmformer::train::LossCurve::default();
        while trainer.step < steps {
            let take = eval_every.min(steps - trainer.step);
            let curve = trainer.train_loop(&mut *gen, take, 0, None)?;
            let valid = trainer.evaluate(&eval_art, &mut *gen, Split::Valid, 4)?;
            for (s, l) in curve.steps.iter().zip(&curve.losses) {
                full_curve.push(*s, *l);
            }
            log.log(&[trainer.step as f64, curve.tail_mean(10) as f64, valid.metric])?;
            println!(
                "step {:>4}: train loss {:.4}  valid ppl {:>8.2}  ({:.2} steps/s)",
                trainer.step,
                curve.tail_mean(10),
                valid.metric,
                trainer.step as f64 / t0.elapsed().as_secs_f64()
            );
        }
        log.flush()?;
        print!("{}", ascii_curve(name, &full_curve.downsample(60), 60));
        let test = trainer.evaluate(&eval_art, &mut *gen, Split::Test, 8)?;
        println!("final test ppl: {:.2} ({} steps in {:.0}s)\n",
                 test.metric, steps, t0.elapsed().as_secs_f64());
        trainer.save_checkpoint(&coord.runs_dir.join(format!("{name}.ckpt.bin")))?;
        finals.push((name.clone(), test.metric));
    }

    if finals.len() >= 2 {
        println!("== e2e comparison (lower is better) ==");
        for (n, ppl) in &finals {
            println!("  {n:<20} test ppl {ppl:.2}");
        }
        if finals[0].1 < finals[1].1 {
            println!("FMMformer beats the linear baseline — matches the paper's claim.");
        } else {
            println!("NOTE: ordering differs from the paper at this step budget.");
        }
    }
    Ok(())
}
