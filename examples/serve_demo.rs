//! Serving demo: train a small FMMformer text classifier, then serve it
//! through the dynamic-batching router and report quality + latency.
//!
//! Demonstrates the full production loop: train → checkpoint → serve the
//! checkpoint through batch-size-bucketed AOT executables → measure
//! accuracy, throughput and batching efficiency.
//!
//!     make artifacts-lra && cargo run --release --example serve_demo -- \
//!         --train-steps 120 --requests 64

use std::time::Duration;

use anyhow::{anyhow, Result};
use fmmformer::cli::Args;
use fmmformer::coordinator::Coordinator;
use fmmformer::data::{text_cls::TextCls, Split, TaskGen};
use fmmformer::serve::{ServeConfig, Server};
use fmmformer::train::Trainer;

const BUCKETS: [&str; 3] = ["serve_text_fmm2_b1", "serve_text_fmm2_b4", "serve_text_fmm2_b8"];

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let train_steps = args.usize_or("train-steps", 120)?;
    let n_requests = args.usize_or("requests", 64)?;
    let dir = fmmformer::artifacts_dir(args.get("artifacts"));
    let coord = Coordinator::new(&dir, 0)?;

    // 1. Train (or reuse) the classifier the server will host.
    let ckpt = coord.runs_dir.join("lra_text_fmm2_band5.ckpt.bin");
    let mut trainer = Trainer::new(&coord.rt, "lra_text_fmm2_band5")?;
    let mut gen = coord.generator("lra_text_fmm2_band5")?;
    if ckpt.exists() {
        println!("reusing checkpoint {ckpt:?}");
        trainer.load_checkpoint(&ckpt)?;
    } else {
        println!("training text classifier for {train_steps} steps...");
        trainer.train_loop(&mut *gen, train_steps, train_steps / 3, None)?;
        std::fs::create_dir_all(&coord.runs_dir).ok();
        trainer.save_checkpoint(&ckpt)?;
    }
    let leaves = trainer.params().download().map_err(|e| anyhow!(e))?;
    let seq_len = trainer.art.manifest.seq_len()?;
    drop(trainer);

    // 2. Serve it.
    let server = Server::start(
        dir,
        &BUCKETS,
        leaves,
        ServeConfig { max_wait: Duration::from_millis(4), pad_id: 0 },
    )?;
    println!("server up (buckets B=1/4/8); firing {n_requests} concurrent requests");

    // 3. Concurrent clients with known labels -> accuracy + latency.
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for c in 0..n_requests {
        let client = server.client();
        handles.push(std::thread::spawn(move || -> Result<(bool, f64)> {
            let mut g = TextCls::new(seq_len, 1000 + c as u64);
            let b = g.batch(Split::Test, 1);
            let label = b.targets.data()[0];
            let resp = client.infer(b.tokens.row(0).to_vec())?;
            let pred = if resp.logits[1] > resp.logits[0] { 1 } else { 0 };
            Ok((pred == label, resp.latency.as_secs_f64()))
        }));
    }
    let mut correct = 0usize;
    let mut lats = vec![];
    for h in handles {
        let (ok, lat) = h.join().map_err(|_| anyhow!("client panicked"))??;
        correct += ok as usize;
        lats.push(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = server.shutdown();

    println!(
        "\naccuracy {}/{} = {:.1}%  |  {:.1} req/s  p50 {:.1} ms  p95 {:.1} ms",
        correct,
        n_requests,
        100.0 * correct as f64 / n_requests as f64,
        n_requests as f64 / wall,
        lats[lats.len() / 2] * 1e3,
        lats[lats.len() * 95 / 100] * 1e3,
    );
    println!(
        "batches {}  mean occupancy {:.2}  padding waste {:.2}x  exec {:.2}s",
        stats.batches,
        stats.mean_occupancy(),
        stats.mean_padding_waste(),
        stats.exec_secs
    );
    Ok(())
}
