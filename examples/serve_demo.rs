//! Serving demo: the incremental streaming decoder (always runs), then
//! the classifier router over AOT artifacts (skips if absent).
//!
//! Part 1 streams tokens through the session-based decode engine —
//! per-token O(1) work via `FmmDecodeState`, micro-batched across
//! concurrent sessions — and pins its logits against the O(N²) batch
//! forward. Part 2 is the original production loop: train → checkpoint
//! → serve through batch-size-bucketed AOT executables → measure
//! accuracy, throughput and batching efficiency.
//!
//!     cargo run --release --example serve_demo               # part 1 only
//!     make artifacts-lra && cargo run --release --example serve_demo -- \
//!         --train-steps 120 --requests 64                    # both parts

use std::time::Duration;

use anyhow::{anyhow, Result};
use fmmformer::cli::Args;
use fmmformer::coordinator::Coordinator;
use fmmformer::data::{text_cls::TextCls, Split, TaskGen};
use fmmformer::serve::decode::{DecodeConfig, DecodeServer, DecodeServerConfig, HostDecoder};
use fmmformer::serve::{ServeConfig, Server};
use fmmformer::train::Trainer;

const BUCKETS: [&str; 3] = ["serve_text_fmm2_b1", "serve_text_fmm2_b4", "serve_text_fmm2_b8"];

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    decode_demo(&args)?;
    artifact_demo(&args)
}

/// Part 1: session-based incremental decoding (host-side, no artifacts).
fn decode_demo(args: &Args) -> Result<()> {
    let sessions = args.usize_or("sessions", 4)?;
    let tokens = args.usize_or("tokens", 96)?;
    let cfg = DecodeConfig::default();
    let vocab = cfg.vocab;

    // Exactness: one stream against the batch forward pass.
    let model = HostDecoder::new(cfg.clone())?;
    let probe: Vec<i32> = (0..32).map(|t| (t * 5 % vocab) as i32).collect();
    let batch = model.forward_batch(&probe)?;
    let server = DecodeServer::start(model, DecodeServerConfig::default());
    let client = server.client();
    let max_diff =
        fmmformer::serve::decode::probe_exactness(&client, &batch, &probe)?;

    // Throughput: concurrent greedy-decoding sessions (shared harness).
    let t0 = std::time::Instant::now();
    fmmformer::serve::decode::run_greedy_sessions(&client, sessions, tokens, vocab)?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!(
        "decode engine: {sessions} sessions x {tokens} tokens -> {:.0} tok/s | \
         incremental vs batch max |diff| {max_diff:.2e} | \
         {} micro-batches (mean {:.1} steps)",
        (sessions * tokens) as f64 / wall,
        stats.micro_batches,
        stats.mean_micro_batch(),
    );
    Ok(())
}

/// Part 2: the dynamic-batching router over AOT artifacts.
fn artifact_demo(args: &Args) -> Result<()> {
    let train_steps = args.usize_or("train-steps", 120)?;
    let n_requests = args.usize_or("requests", 64)?;
    let dir = fmmformer::artifacts_dir(args.get("artifacts"));
    let coord = match Coordinator::new(&dir, 0) {
        Ok(c) => c,
        Err(e) => {
            println!("SKIP artifact serving (no runtime: {e:#}); run `make artifacts-lra`");
            return Ok(());
        }
    };

    // 1. Train (or reuse) the classifier the server will host.
    let ckpt = coord.runs_dir.join("lra_text_fmm2_band5.ckpt.bin");
    let mut trainer = match Trainer::new(&coord.rt, "lra_text_fmm2_band5") {
        Ok(t) => t,
        Err(e) => {
            println!("SKIP artifact serving ({e:#}); run `make artifacts-lra`");
            return Ok(());
        }
    };
    let mut gen = coord.generator("lra_text_fmm2_band5")?;
    if ckpt.exists() {
        println!("reusing checkpoint {ckpt:?}");
        trainer.load_checkpoint(&ckpt)?;
    } else {
        println!("training text classifier for {train_steps} steps...");
        trainer.train_loop(&mut *gen, train_steps, train_steps / 3, None)?;
        std::fs::create_dir_all(&coord.runs_dir).ok();
        trainer.save_checkpoint(&ckpt)?;
    }
    let leaves = trainer.params().download().map_err(|e| anyhow!(e))?;
    let seq_len = trainer.art.manifest.seq_len()?;
    drop(trainer);

    // 2. Serve it.
    let server = Server::start(
        dir,
        &BUCKETS,
        leaves,
        ServeConfig { max_wait: Duration::from_millis(4), pad_id: 0 },
    )?;
    println!("server up (buckets B=1/4/8); firing {n_requests} concurrent requests");

    // 3. Concurrent clients with known labels -> accuracy + latency.
    let t0 = std::time::Instant::now();
    let mut handles = vec![];
    for c in 0..n_requests {
        let client = server.client();
        handles.push(std::thread::spawn(move || -> Result<(bool, f64)> {
            let mut g = TextCls::new(seq_len, 1000 + c as u64);
            let b = g.batch(Split::Test, 1);
            let label = b.targets.data()[0];
            let resp = client.infer(b.tokens.row(0).to_vec())?;
            let pred = if resp.logits[1] > resp.logits[0] { 1 } else { 0 };
            Ok((pred == label, resp.latency.as_secs_f64()))
        }));
    }
    let mut correct = 0usize;
    let mut lats = vec![];
    for h in handles {
        let (ok, lat) = h.join().map_err(|_| anyhow!("client panicked"))??;
        correct += ok as usize;
        lats.push(lat);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    let stats = server.shutdown();

    println!(
        "\naccuracy {}/{} = {:.1}%  |  {:.1} req/s  p50 {:.1} ms  p95 {:.1} ms",
        correct,
        n_requests,
        100.0 * correct as f64 / n_requests as f64,
        n_requests as f64 / wall,
        lats[lats.len() / 2] * 1e3,
        lats[lats.len() * 95 / 100] * 1e3,
    );
    println!(
        "batches {}  mean occupancy {:.2}  padding waste {:.2}x  exec {:.2}s",
        stats.batches,
        stats.mean_occupancy(),
        stats.mean_padding_waste(),
        stats.exec_secs
    );
    Ok(())
}
